"""HGNN model correctness: stage outputs, baseline-vs-fused consistency,
and a brute-force GAT check on a tiny graph."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core import stages
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


def _run(model_name, tiny_hg, fused=False, **kw):
    # monkeypatch dataset tables for the tiny graph
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"
    kw = {"max_degree": 12, "max_instances": 4, **kw}
    cfg = HGNNConfig(model=model_name, dataset="tiny", hidden=16, n_heads=4,
                     n_classes=3, fused=fused, **kw)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    return m, params, batch


@pytest.mark.parametrize("model", ["han", "rgcn", "magnn"])
def test_forward_shapes_finite(model, tiny_hg):
    m, params, batch = _run(model, tiny_hg)
    logits = m.forward(params, batch)
    assert logits.shape == (40, 3)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("model", ["han", "rgcn"])
def test_fused_path_close_to_baseline(model, tiny_hg):
    """Stacked/padded (optimized) vs CSR (baseline): same math as long as no
    neighbor is dropped (max_degree >= true max degree)."""
    m1, p1, b1 = _run(model, tiny_hg, fused=False)
    m2, p2, b2 = _run(model, tiny_hg, fused=True, max_degree=48)
    # identical params (same init key/structure modulo stacking)
    l1 = m1.forward(p1, b1)
    l2 = m2.forward(p2, b2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=2e-3)


def test_han_degree_bucketed_matches_stacked(tiny_hg):
    """Degree-bucketed NA layout (2-3 K-caps) is a pure layout change: the
    forward must match the single-K stacked fused path exactly."""
    m1, p1, b1 = _run("han", tiny_hg, fused=True, max_degree=48)
    m2, p2, b2 = _run("han", tiny_hg, fused=True, max_degree=48,
                      degree_buckets=3)
    assert "buckets" in b2 and "nbr" not in b2
    # layout strictly smaller than the single-K pad
    padded = sum(t[1].size for bk in b2["buckets"] for t in bk)
    assert padded < b1["nbr"].size
    l1 = m1.forward(p1, b1)
    l2 = m2.forward(p2, b2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_gat_csr_matches_padded(tiny_hg):
    from repro.core import metapath as mp

    rng = np.random.default_rng(3)
    csr = mp.build_csr(tiny_hg, ["M", "D", "M"])
    seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
    pad = mp.build_padded(tiny_hg, ["M", "D", "M"], max_degree=48)
    n, h, dh = 40, 4, 8
    hfeat = jnp.asarray(rng.standard_normal((n, h, dh)), jnp.float32)
    p = stages.init_gat(jax.random.key(1), h, dh)
    a = stages.gat_aggregate_csr(p, hfeat, hfeat, jnp.asarray(seg),
                                 jnp.asarray(idx), n)
    b = stages.gat_aggregate_padded(p, hfeat, hfeat, jnp.asarray(pad.nbr),
                                    jnp.asarray(pad.mask))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_gat_bruteforce_single_head():
    """3-node chain, 1 head: hand-computed GAT attention."""
    h = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]])[:, None, :]  # [3,1,2]
    p = {"a_dst": jnp.asarray([[0.5, 0.0]]), "a_src": jnp.asarray([[0.0, 0.5]])}
    nbr = jnp.asarray([[0, 1], [1, 2], [2, 0]], jnp.int32)
    mask = jnp.ones((3, 2), jnp.float32)
    out = stages.gat_aggregate_padded(p, h, h, nbr, mask)
    # manual for node 0: e = lrelu(a_dst.h0 + a_src.h_j) over j in {0,1}
    e0 = np.array([0.5 + 0.0, 0.5 + 0.5])
    a0 = np.exp(e0 - e0.max())
    a0 = a0 / a0.sum()
    want0 = a0[0] * np.array([1.0, 0.0]) + a0[1] * np.array([0.0, 1.0])
    np.testing.assert_allclose(np.asarray(out)[0, 0], want0, rtol=1e-5)


def test_semantic_attention_convexity():
    """SA output is a convex combination of per-metapath results."""
    from repro.core import semantics

    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((3, 20, 8)), jnp.float32)
    p = semantics.init_semantic_attention(jax.random.key(0), 8, 16)
    out = semantics.semantic_attention(p, z)
    lo = np.asarray(z).min(axis=0)
    hi = np.asarray(z).max(axis=0)
    assert (np.asarray(out) >= lo - 1e-5).all()
    assert (np.asarray(out) <= hi + 1e-5).all()


def test_rgcn_semantic_sum_is_plain_sum():
    from repro.core import semantics

    z = jnp.asarray(np.random.default_rng(0).standard_normal((4, 10, 6)),
                    jnp.float32)
    # rtol accounts for accumulation order: XLA sums sequentially, numpy
    # pairwise — they differ in the last ulp for fp32
    np.testing.assert_allclose(np.asarray(semantics.semantic_sum(z)),
                               np.asarray(z).sum(0), rtol=1e-5)


def test_gcn_reddit_like():
    from repro.configs.base import HGNNConfig
    from repro.data.synthetic import make_reddit_like

    hg = make_reddit_like(scale=0.005)
    cfg = HGNNConfig(model="gcn", dataset="reddit", hidden=16, n_classes=5)
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(0), batch)
    logits = m.forward(params, batch)
    assert logits.shape[1] == 5 and bool(jnp.isfinite(logits).all())
