"""Property tests for the request-path neighbor sampler (repro.serve.sampler).

The four ISSUE invariants, each over randomized heterographs / fan-outs /
target sets (hypothesis, or the deterministic conftest stub on minimal CI
images):

  1. soundness — every neighbor a sampled minibatch wires up is an edge of
     the full graph (metapath reachability for HAN, relation in-neighbors
     for RGCN, consecutive relation hops for MAGNN instances);
  2. relabeling is a bijection between the extracted vertex set and the
     local id range (and ``target_rows`` inverts it for the request ids);
  3. fan-out caps hold per hop / per metapath / per relation;
  4. every batch's pytree signature (structure + leaf shapes) comes from
     the declared ladder — byte-identical to the warmup ``dummy_batch`` of
     its rung, which is exactly why the jitted executor never recompiles.
"""
import jax
import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import HGNNConfig
from repro.core.hgraph import HeteroGraph, metapath_adjacency
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
from repro.serve.sampler import HGNNSampler

DATASET_METAPATHS["sampt"] = [["M", "D", "M"], ["M", "A", "M"]]
DATASET_TARGET["sampt"] = "M"


def _rand_hg(seed: int) -> HeteroGraph:
    rng = np.random.default_rng(seed)
    nm = int(rng.integers(12, 40))
    nd = int(rng.integers(5, 16))
    na = int(rng.integers(6, 20))
    counts = {"M": nm, "D": nd, "A": na}
    dims = {"M": 6, "D": 5, "A": 4}
    feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
             for t, n in counts.items()}

    def rr(ns, nd_, e):
        r = rng.integers(0, ns, e)
        c = rng.integers(0, nd_, e)
        return sp.csr_matrix((np.ones(e, np.float32), (r, c)),
                             shape=(ns, nd_))

    md = rr(nm, nd, 3 * nm)
    ma = rr(nm, na, 3 * nm)
    g = HeteroGraph(
        counts, feats,
        {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
         ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
        name="sampt")
    g.validate()
    return g


def _cfg(model: str, fanout: int, **kw) -> HGNNConfig:
    return HGNNConfig(model=model, dataset="sampt", hidden=8, n_heads=2,
                      n_classes=3, max_degree=6, max_instances=3,
                      fused=True, fanout=fanout, **kw)


def _sampler(model: str, hg: HeteroGraph, fanout: int, **kw) -> HGNNSampler:
    cfg = _cfg(model, fanout, **kw)
    return HGNNSampler(get_model(cfg).plan(), cfg, hg)


def _targets(hg: HeteroGraph, seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1000)
    return rng.integers(0, hg.node_counts["M"], size=n).astype(np.int64)


def _check_bijection(sb) -> None:
    for t, ids in sb.local.items():
        assert len(np.unique(ids)) == len(ids), t  # injective
        assert ids.min() >= 0 if len(ids) else True
    ids = sb.local["M"]
    # target_rows is the relabel inverse for the request ids (duplicates
    # included): local row r holds global vertex target_ids[i]
    for i, r in enumerate(sb.target_rows):
        assert ids[r] == sb.target_ids[i]


def _sig(batch):
    leaves, treedef = jax.tree.flatten(batch)
    return (str(treedef),
            tuple((tuple(getattr(x, "shape", ())),
                   str(getattr(x, "dtype", type(x).__name__)))
                  for x in leaves))


# ---------------------------------------------------------------------------
# 1 + 2 + 3: soundness / bijection / fan-out caps, per layout
# ---------------------------------------------------------------------------

@settings(max_examples=8)
@given(seed=st.integers(0, 10_000), fanout=st.integers(1, 6),
       n_req=st.integers(1, 10), layers=st.integers(1, 2))
def test_han_sampled_edges_exist_and_caps_hold(seed, fanout, n_req, layers):
    hg = _rand_hg(seed)
    smp = _sampler("han", hg, fanout, layers=layers)
    sb = smp.sample(_targets(hg, seed, n_req))
    _check_bijection(sb)
    nbr = np.asarray(sb.batch["nbr"])
    mask = np.asarray(sb.batch["mask"])
    ids = sb.local["M"]
    n_real = len(ids)
    # fan-out cap: the neighbor axis is min(fanout, max_degree) wide
    assert nbr.shape[2] == min(fanout, smp.cfg.max_degree)
    assert mask[:, n_real:].sum() == 0  # rung pads are all-masked
    for p, path in enumerate(smp.plan.metapaths):
        adj = metapath_adjacency(hg, list(path)).toarray()
        for u in range(n_real):
            ks = np.flatnonzero(mask[p, u])
            assert len(ks) <= fanout  # per-row, per-metapath cap
            for k in ks:
                v = nbr[p, u, k]
                assert v < n_real  # wired rows are extracted vertices
                # edge exists in the full graph (build_padded self-loops on)
                assert adj[ids[u], ids[v]] != 0 or ids[u] == ids[v]


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), fanout=st.integers(1, 6),
       n_req=st.integers(1, 10), layers=st.integers(1, 2))
def test_rgcn_sampled_edges_exist_and_caps_hold(seed, fanout, n_req, layers):
    hg = _rand_hg(seed)
    smp = _sampler("rgcn", hg, fanout, layers=layers)
    sb = smp.sample(_targets(hg, seed, n_req))
    _check_bijection(sb)
    for key, (nbr, mask) in sb.batch["rels"].items():
        s, _, d = key
        nbr, mask = np.asarray(nbr), np.asarray(mask)
        assert nbr.shape == (sb.batch["counts"][d],
                             min(fanout, smp.cfg.max_degree))
        ids_d, ids_s = sb.local[d], sb.local[s]
        for u in range(len(ids_d)):
            ks = np.flatnonzero(mask[u])
            assert len(ks) <= fanout
            full_nbrs = set(hg.in_neighbors(key, int(ids_d[u])).tolist())
            for k in ks:
                v = nbr[u, k]
                assert v < len(ids_s)
                assert int(ids_s[v]) in full_nbrs


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), fanout=st.integers(1, 4),
       n_req=st.integers(1, 8), layers=st.integers(1, 2))
def test_magnn_sampled_instances_are_real_paths(seed, fanout, n_req, layers):
    hg = _rand_hg(seed)
    smp = _sampler("magnn", hg, fanout, layers=layers)
    sb = smp.sample(_targets(hg, seed, n_req))
    _check_bijection(sb)
    rels = {(a, b): hg.rel(a, b).toarray()
            for p in smp.plan.metapaths for a, b in zip(p, p[1:])}
    for (nodes, mask), path in zip(sb.batch["instances"],
                                   smp.plan.metapaths):
        nodes, mask = np.asarray(nodes), np.asarray(mask)
        # fan-out cap: instances-per-target axis
        assert nodes.shape[1] == min(fanout, smp.cfg.max_instances)
        n_real = len(sb.local["M"])
        assert mask[n_real:].sum() == 0
        for u in range(n_real):
            for i in np.flatnonzero(mask[u]):
                gl = [int(sb.local[ty][nodes[u, i, j]])
                      for j, ty in enumerate(path)]
                assert gl[0] == int(sb.local["M"][u])  # anchored at the row
                for j, (a, b) in enumerate(zip(path, path[1:])):
                    assert rels[(a, b)][gl[j], gl[j + 1]] != 0, (path, gl)


# ---------------------------------------------------------------------------
# 4: shapes come only from the declared ladder
# ---------------------------------------------------------------------------

@settings(max_examples=4)
@given(seed=st.integers(0, 10_000), fanout=st.integers(1, 5),
       model=st.sampled_from(["han", "rgcn", "magnn"]),
       bucketed=st.booleans())
def test_batch_shapes_come_from_the_ladder(seed, fanout, model, bucketed):
    hg = _rand_hg(seed)
    kw = {"degree_buckets": 3} if bucketed and model != "magnn" else {}
    smp = _sampler(model, hg, fanout, **kw)
    rung_sigs = [_sig(smp.dummy_batch(i).batch)
                 for i in range(len(smp.ladder))]
    rng = np.random.default_rng(seed)
    for _ in range(6):
        tg = _targets(hg, int(rng.integers(0, 2**31)),
                      int(rng.integers(1, 11)))
        sb = smp.sample(tg)
        assert sb.rung in smp.ladder
        # pytree structure + leaf shapes identical to the warmup batch of
        # the same rung => the jitted forward hits the warmup compilation
        assert _sig(sb.batch) == rung_sigs[sb.rung_index]


@settings(max_examples=4)
@given(seed=st.integers(0, 10_000), n_req=st.integers(1, 6))
def test_truncation_never_drops_targets(seed, n_req):
    """A deliberately starved ladder truncates the frontier (counted in
    meta) but every requested target keeps a real row."""
    hg = _rand_hg(seed)
    cfg = _cfg("han", fanout=6,
               sample_ladder=((8, max(10, n_req + 2)),))
    smp = HGNNSampler(get_model(cfg).plan(), cfg, hg)
    tg = _targets(hg, seed, min(n_req, 8))
    sb = smp.sample(tg)
    _check_bijection(sb)
    row_mask = np.asarray(sb.batch["row_mask"])
    assert (row_mask[sb.target_rows] == 1.0).all()
    assert sb.meta["truncated_rows"] >= 0


def test_pick_rung_prefers_smallest_fit():
    hg = _rand_hg(0)
    cfg = _cfg("han", fanout=2, sample_ladder=((2, 8), (4, 16), (8, 64)))
    smp = HGNNSampler(get_model(cfg).plan(), cfg, hg)
    assert smp.pick_rung(1, {"M": 3}) == 0
    assert smp.pick_rung(3, {"M": 3}) == 1  # targets overflow rung 0
    assert smp.pick_rung(1, {"M": 12}) == 1  # frontier overflows rung 0
    assert smp.pick_rung(8, {"M": 200}) == 2  # falls through: truncation
    with pytest.raises(ValueError, match="overflow"):
        smp.pick_rung(9, {"M": 1})
