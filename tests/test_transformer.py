"""Per-family LM integration: forward / prefill / decode consistency + loss
finiteness + masking semantics. (Family microtests live in test_ssm/test_moe.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.nn import transformer as tf

RNG = np.random.default_rng(4)


def _families(base):
    yield "dense", ModelConfig(name="d", family="dense", **base)
    yield "swa", ModelConfig(name="w", family="dense", sliding_window=16, **base)
    yield "moe", ModelConfig(name="m", family="moe", moe=MoEConfig(
        n_experts=4, top_k=2, d_ff_expert=32, dense_residual_ff=32,
        capacity_factor=8.0), **base)
    yield "ssm", ModelConfig(name="s", family="ssm", ssm=SSMConfig(
        d_state=16, head_dim=8, expand=2, chunk=8), **base)
    yield "hybrid", ModelConfig(name="h", family="hybrid", ssm=SSMConfig(
        d_state=16, head_dim=8, expand=2, chunk=8), shared_attn_period=2, **base)


@pytest.mark.parametrize("fam", ["dense", "swa", "moe", "ssm", "hybrid"])
def test_prefill_decode_match_forward(fam, tiny_cfg_base):
    cfg = dict(_families(tiny_cfg_base))[fam]
    params = tf.init_lm_params(jax.random.key(0), cfg)
    B, S, T0 = 2, 32, 24
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits, _ = tf.lm_forward(params, cfg, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    lg, caches = tf.lm_prefill(params, cfg, tokens[:, :T0])
    np.testing.assert_allclose(lg[:, 0], logits[:, T0 - 1], rtol=1e-2, atol=1e-2)
    caches = tf.graft_prefill_caches(cfg, tf.init_kv_caches(cfg, B, S), caches, T0)
    for t in range(T0, S):
        lg, caches = tf.lm_decode_step(params, cfg, tokens[:, t:t + 1],
                                       caches, jnp.int32(t))
        np.testing.assert_allclose(lg[:, 0], logits[:, t], rtol=1e-2, atol=1e-2)


def test_loss_masking_ignores_pad(tiny_cfg_base):
    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    params = tf.init_lm_params(jax.random.key(0), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    labels = tokens
    l1 = tf.lm_loss(params, cfg, {"tokens": tokens, "labels": labels})
    # mask half the labels: loss changes but stays finite; all-masked -> 0/1 guard
    labels2 = labels.at[:, 8:].set(-1)
    l2 = tf.lm_loss(params, cfg, {"tokens": tokens, "labels": labels2})
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    l3 = tf.lm_loss(params, cfg, {"tokens": tokens,
                                  "labels": jnp.full_like(labels, -1)})
    assert abs(float(l3)) < 10.0  # aux-only, no NaN


def test_chunked_ce_matches_dense():
    d, v, b, s = 16, 37, 2, 12
    h = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    head = jnp.asarray(RNG.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, v, (b, s)), jnp.int32)
    mask = jnp.ones((b, s), jnp.float32)
    got = tf.chunked_ce(h, head, labels, mask, chunk_tokens=5)
    logits = (h @ head).astype(jnp.float32)
    want = (jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_vlm_extra_embeds(tiny_cfg_base):
    cfg = ModelConfig(name="v", family="vlm", frontend="vision",
                      n_frontend_embeds=8, **tiny_cfg_base)
    params = tf.init_lm_params(jax.random.key(0), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 24)), jnp.int32)
    ve = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    logits, _ = tf.lm_forward(params, cfg, tokens, extra_embeds=ve)
    assert logits.shape == (2, 24, cfg.vocab)
    # vision content must influence text logits
    logits2, _ = tf.lm_forward(params, cfg, tokens, extra_embeds=ve * 2.0)
    assert float(jnp.abs(logits - logits2).max()) > 1e-5


def test_encdec_roundtrip(tiny_cfg_base):
    from repro.nn import encdec as ed

    base = dict(tiny_cfg_base)
    cfg = ModelConfig(name="e", family="encdec", enc_layers=2, dec_layers=2,
                      frontend="audio", **base)
    params = ed.init_encdec_params(jax.random.key(0), cfg)
    frames = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model)) * 0.3,
                         jnp.float32)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 24)), jnp.int32)
    logits = ed.encdec_forward(params, cfg, frames, tokens)
    T0 = 16
    lg, caches = ed.encdec_prefill(params, cfg, frames, tokens[:, :T0])
    np.testing.assert_allclose(lg[:, 0], logits[:, T0 - 1], rtol=1e-3, atol=1e-3)
    full = ed.init_encdec_caches(cfg, 2, 32, 16)
    caches = {k: jax.lax.dynamic_update_slice(
        full[k], caches[k].astype(full[k].dtype), (0,) * full[k].ndim)
        for k in full}
    for t in range(T0, 24):
        lg, caches = ed.encdec_decode_step(params, cfg, tokens[:, t:t + 1],
                                           caches, jnp.int32(t))
        np.testing.assert_allclose(lg[:, 0], logits[:, t], rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_long_decode(tiny_cfg_base):
    """Decode far past the window: ring cache result == full-cache windowed
    attention."""
    base = dict(tiny_cfg_base)
    cfg_ring = ModelConfig(name="w", family="dense", sliding_window=8, **base)
    params = tf.init_lm_params(jax.random.key(0), cfg_ring)
    B, S = 1, 32
    tokens = jnp.asarray(RNG.integers(0, cfg_ring.vocab, (B, S)), jnp.int32)
    logits, _ = tf.lm_forward(params, cfg_ring, tokens)  # windowed full fwd
    caches = tf.init_kv_caches(cfg_ring, B, S)  # ring size = 8
    assert caches[0]["k"].shape[2] == 8
    lg = None
    for t in range(S):
        lg, caches = tf.lm_decode_step(params, cfg_ring, tokens[:, t:t + 1],
                                       caches, jnp.int32(t))
    np.testing.assert_allclose(lg[:, 0], logits[:, -1], rtol=1e-2, atol=1e-2)
