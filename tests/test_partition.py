"""`repro.dist.partition`: partition/relabel round-trip invariants.

Pins the host-side partitioner contracts the partitioned execution mode
rests on: ownership is a capacity-bounded exact cover, every (masked-valid)
edge of the original layout survives relabeling exactly once and maps back
to the same global endpoints, the halo index maps point at rows the owner
actually populates, and the device-side ``gather_halo`` exchange fetches
exactly the rows the maps name.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
from repro.dist import partition as dp


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"


def _cfg(model, **kw):
    _tiny_tables()
    kw = {"max_degree": 48, "max_instances": 4, **kw}
    return HGNNConfig(model=model, dataset="tiny", hidden=16, n_heads=4,
                      n_classes=3, **kw)


# ---------------------------------------------------------------------------
# assignment primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(40, 4), (17, 4), (5, 8), (0, 2)])
def test_edge_cut_assign_exact_cover_and_capacity(n, k):
    rng = np.random.default_rng(0)
    neigh = [rng.integers(0, max(n, 1), rng.integers(0, 6)).astype(np.int64)
             for _ in range(n)]
    owner = dp.edge_cut_assign(neigh, max(n, 1), k)
    assert owner.shape == (n,)
    if n:
        assert owner.min() >= 0 and owner.max() < k
        cap = -(-n // k)
        assert np.bincount(owner, minlength=k).max() <= cap


def test_edge_cut_assign_clusters_shared_neighbors():
    # two cliques reading disjoint token sets must not be interleaved
    neigh = [np.array([0, 1, 2])] * 4 + [np.array([10, 11, 12])] * 4
    owner = dp.edge_cut_assign(neigh, 13, 2)
    assert len(set(owner[:4])) == 1 and len(set(owner[4:])) == 1
    assert owner[0] != owner[4]


def test_reference_assign_majority_and_capacity():
    votes = np.zeros((8, 2))
    votes[:6, 1] = 5.0  # six vertices read mostly by partition 1
    owner = dp.reference_assign(votes, 2)
    assert np.bincount(owner, minlength=2).max() <= 4  # cap = ceil(8/2)
    assert (owner[:6] == 1).sum() == 4  # majority honoured up to capacity


def test_type_partition_is_a_bijection():
    owner = np.array([1, 0, 1, 2, 0, 2, 1], np.int32)
    tp = dp.build_type_partition(owner, 3)
    flat = tp.flat
    assert len(np.unique(flat)) == len(owner)  # injective into own slots
    own_flat = tp.own.reshape(-1)
    mask_flat = tp.own_mask.reshape(-1)
    assert (mask_flat[flat] == 1.0).all()
    assert (own_flat[flat] == np.arange(len(owner))).all()  # round trip


# ---------------------------------------------------------------------------
# partitioned-batch round-trip invariants
# ---------------------------------------------------------------------------


def _roundtrip_stacked(tiny_hg, k):
    cfg_ref = _cfg("han", fused=True)
    ref = get_model(cfg_ref).prepare(tiny_hg)
    nbr, mask = np.asarray(ref["nbr"]), np.asarray(ref["mask"])
    m = get_model(_cfg("han", fused=True, partitions=k))
    b = m.prepare(tiny_hg)
    part = b["part"]
    own = np.asarray(part["own_mask"]["M"])
    inv = np.asarray(part["inv"])
    n_max = own.shape[1]
    # ownership covers every target row exactly once
    assert (own.reshape(-1)[inv] == 1.0).all()
    assert own.sum() == nbr.shape[1]
    own_ids = np.asarray(part["own"]["M"]).astype(np.int64)
    # inv and own agree: the flat own-order slot of row g holds g
    assert (own_ids.reshape(-1)[inv] == np.arange(nbr.shape[1])).all()
    # reconstruct the global layout from the partition-local one
    local_tab = _local_to_global(part, "M")  # [K, n_max + H]
    nbr_p, mask_p = np.asarray(part["nbr"]), np.asarray(part["mask"])
    total_edges = 0
    for j in range(k):
        rows = np.flatnonzero(own[j] > 0)
        for i in rows:
            g = int(own_ids[j, i])
            for p in range(nbr.shape[0]):
                valid = mask_p[j, p, i] > 0
                total_edges += int(valid.sum())
                # same neighbor multiset, mapped back to global ids
                got = np.sort(local_tab[j, nbr_p[j, p, i][valid]])
                want = np.sort(nbr[p, g][mask[p, g] > 0])
                np.testing.assert_array_equal(got, want)
    # every edge covered exactly once
    assert total_edges == int((mask > 0).sum())
    return part


@pytest.mark.parametrize("k", [1, 3])
def test_stacked_partition_roundtrip(tiny_hg, k):
    part = _roundtrip_stacked(tiny_hg, k)
    meta = part["meta"]
    assert 0 <= meta["cut_edges"] <= meta["edges_total"]
    if k == 1:
        assert meta["cut_edges"] == 0
        assert np.asarray(part["halo_src"]["M"]).shape[1] == 0


def test_halo_maps_point_at_populated_remote_rows(tiny_hg):
    m = get_model(_cfg("han", fused=True, partitions=3))
    part = m.prepare(tiny_hg)["part"]
    own = np.asarray(part["own_mask"]["M"])
    halo_src = np.asarray(part["halo_src"]["M"])
    halo_mask = np.asarray(part["halo_mask"]["M"])
    n_max = own.shape[1]
    for j in range(halo_src.shape[0]):
        valid = halo_src[j][halo_mask[j] > 0]
        # every halo entry names a populated slot owned by ANOTHER partition
        assert (own.reshape(-1)[valid] == 1.0).all()
        assert (valid // n_max != j).all()
        assert len(np.unique(valid)) == len(valid)  # no duplicate fetches


def _local_to_global(part, ty):
    """[K, n_max + H_max] table: partition-local coordinate -> global id."""
    own_ids = np.asarray(part["own"][ty]).astype(np.int64)
    halo_src = np.asarray(part["halo_src"][ty])
    halo_ids = own_ids.reshape(-1)[halo_src]
    return np.concatenate([own_ids, halo_ids], axis=1)


def test_relational_partition_roundtrip(tiny_hg):
    k = 3
    ref = get_model(_cfg("rgcn", fused=True)).prepare(tiny_hg)
    m = get_model(_cfg("rgcn", fused=True, partitions=k))
    b = m.prepare(tiny_hg)
    part = b["part"]
    assert sorted(b["rels"]) == sorted(ref["rels"])  # init keys preserved
    inv = np.asarray(part["inv"])
    own_t = np.asarray(part["own_mask"]["M"])
    own_ids_t = np.asarray(part["own"]["M"]).astype(np.int64)
    assert (own_t.reshape(-1)[inv] == 1.0).all()
    for key, (nbr_p, mask_p) in part["rels"].items():
        s = key[0]
        assert key[2] == "M"  # only relations into the target are kept
        nbr_ref, mask_ref = (np.asarray(x) for x in ref["rels"][key])
        local_tab = _local_to_global(part, s)
        nbr_pn, mask_pn = np.asarray(nbr_p), np.asarray(mask_p)
        total = 0
        for j in range(k):
            for i in np.flatnonzero(own_t[j] > 0):
                g = int(own_ids_t[j, i])
                valid = mask_pn[j, i] > 0
                total += int(valid.sum())
                got = np.sort(local_tab[j, nbr_pn[j, i][valid]])
                want = np.sort(nbr_ref[g][mask_ref[g] > 0])
                np.testing.assert_array_equal(got, want)
        assert total == int((mask_ref > 0).sum())  # every edge exactly once


def test_instances_partition_roundtrip(tiny_hg):
    k = 3
    m_ref = get_model(_cfg("magnn"))
    ref = m_ref.prepare(tiny_hg)
    m = get_model(_cfg("magnn", partitions=k))
    b = m.prepare(tiny_hg)
    part = b["part"]
    own_t = np.asarray(part["own_mask"]["M"])
    own_ids_t = np.asarray(part["own"]["M"]).astype(np.int64)
    assert (np.asarray(part["own_mask"]["M"]).reshape(-1)[
        np.asarray(part["inv"])] == 1.0).all()
    tabs = {ty: _local_to_global(part, ty) for ty in part["own"]}
    for (nodes_ref, mask_ref), (nodes_p, mask_p), path in zip(
            ref["instances"], part["instances"], m.plan().metapaths):
        nodes_ref, mask_ref = np.asarray(nodes_ref), np.asarray(mask_ref)
        nodes_p, mask_p = np.asarray(nodes_p), np.asarray(mask_p)
        assert mask_p.sum() == mask_ref.sum()  # instance count preserved
        for j in range(k):
            for i in np.flatnonzero(own_t[j] > 0):
                g = int(own_ids_t[j, i])
                valid = mask_p[j, i] > 0
                assert valid.sum() == (mask_ref[g] > 0).sum()
                # each position's local ids map back to the same global ids
                for pos, ty in enumerate(path):
                    got = np.sort(tabs[ty][j, nodes_p[j, i][valid][:, pos]])
                    want = np.sort(nodes_ref[g][mask_ref[g] > 0][:, pos])
                    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# the device-side halo exchange
# ---------------------------------------------------------------------------


def test_gather_halo_matches_flat_numpy_gather():
    rng = np.random.default_rng(3)
    k, n, h, d = 4, 6, 5, 8
    h_own = rng.standard_normal((k, n, d)).astype(np.float32)
    halo_src = rng.integers(0, k * n, (k, h)).astype(np.int32)
    got = np.asarray(dp.gather_halo(jax.numpy.asarray(h_own),
                                    jax.numpy.asarray(halo_src)))
    want = h_own.reshape(k * n, d)[halo_src]
    np.testing.assert_allclose(got, want)


def test_gather_halo_empty_halo():
    h_own = jax.numpy.ones((2, 3, 4))
    halo_src = jax.numpy.zeros((2, 0), jax.numpy.int32)
    assert dp.gather_halo(h_own, halo_src).shape == (2, 0, 4)


def test_partition_batch_rejects_unsupported_layouts(tiny_hg):
    with pytest.raises(ValueError, match="stacked layout"):
        get_model(_cfg("han", fused=True, degree_buckets=3,
                       partitions=2)).plan()
    with pytest.raises(ValueError, match="padded per-relation"):
        get_model(_cfg("rgcn", fused=False, partitions=2)).plan()
    from repro.core.models.gcn import GCN

    with pytest.raises(ValueError, match="no partitioned execution"):
        GCN(HGNNConfig(model="gcn", dataset="reddit", partitions=2)).plan()
