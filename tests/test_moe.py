"""MoE routing properties: gate normalization, capacity enforcement,
no-drop consistency, aux-loss sanity, expert utilization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.nn.moe import _capacity, init_moe, moe_block

RNG = np.random.default_rng(3)


def _block(e=4, k=2, ff=16, d=8, cf=2.0, dense=0):
    moe = MoEConfig(n_experts=e, top_k=k, d_ff_expert=ff,
                    dense_residual_ff=dense, capacity_factor=cf)
    params = init_moe(jax.random.key(0), d, moe, 2, "float32")
    return moe, params


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([8, 64, 100]), e=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2), cf=st.sampled_from([1.0, 1.5, 4.0]))
def test_capacity_formula(t, e, k, cf):
    moe = MoEConfig(n_experts=e, top_k=k, d_ff_expert=8, capacity_factor=cf)
    c = _capacity(t, moe)
    assert c >= 8 and c % 8 == 0
    assert c >= t * k / e * cf - 8


def test_moe_output_finite_and_shaped():
    moe, params = _block()
    x = jnp.asarray(RNG.standard_normal((2, 16, 8)) * 0.5, jnp.float32)
    out, aux = moe_block(params, x, moe)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and np.isfinite(float(aux))
    assert float(aux) >= 1.0 - 1e-3  # Switch aux >= 1 at any routing


def test_moe_no_drop_equals_manual_topk():
    """With capacity >= all tokens, output == explicit per-token expert mix."""
    moe, params = _block(e=4, k=2, cf=50.0)
    x = jnp.asarray(RNG.standard_normal((1, 12, 8)) * 0.5, jnp.float32)
    out, _ = moe_block(params, x, moe)
    xt = x.reshape(-1, 8)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, 2)
    gw = gw / gw.sum(-1, keepdims=True)
    want = []
    for t in range(12):
        acc = 0
        for j in range(2):
            e_id = int(gi[t, j])
            h = jax.nn.silu(xt[t] @ params["w_gate"][e_id]) * (xt[t] @ params["w_up"][e_id])
            acc = acc + gw[t, j] * (h @ params["w_down"][e_id])
        want.append(acc)
    np.testing.assert_allclose(out.reshape(-1, 8), jnp.stack(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_dense_residual_added():
    moe, params = _block(dense=16)
    x = jnp.asarray(RNG.standard_normal((1, 8, 8)) * 0.5, jnp.float32)
    out, _ = moe_block(params, x, moe)
    from repro.nn.mlp import mlp_block

    params_nodense = {k: v for k, v in params.items() if k != "dense"}
    base, _ = moe_block(params_nodense, x, moe)
    np.testing.assert_allclose(out - base, mlp_block(params["dense"], x),
                               rtol=1e-3, atol=1e-3)


def test_capacity_drops_tokens_when_tight():
    """With capacity 8 (minimum) and many tokens routed to one expert, the
    overflow contributes zero (tokens dropped, residual carries them)."""
    moe, params = _block(e=2, k=1, cf=0.01)
    # biased router + positive inputs: every token routes to expert 0
    params = dict(params)
    params["router"] = jnp.asarray(np.tile(np.array([[10.0, -10.0]]), (8, 1)),
                                   jnp.float32)
    x = jnp.abs(jnp.asarray(RNG.standard_normal((1, 64, 8)) * 0.5, jnp.float32))
    out, aux = moe_block(params, x, moe)
    # capacity = max(8, ceil(64*1/2*0.01)) = 8 -> exactly 8 tokens served
    served = (jnp.abs(out.reshape(-1, 8)).sum(-1) > 1e-7).sum()
    assert int(served) == 8, int(served)
