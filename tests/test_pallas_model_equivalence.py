"""Model-level equivalence: HAN's optimized path with the Pallas NA kernel
(interpret mode) must match the pure-XLA stages end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"


def _force_interpret(monkeypatch, name):
    """Force an ops wrapper onto the Pallas path in interpret mode."""
    from repro.kernels import ops

    orig = getattr(ops, name)
    monkeypatch.setattr(
        ops, name,
        lambda *args, use_pallas=False, interpret=False, **kw:
        orig(*args, use_pallas=True, interpret=True, **kw))


def test_han_pallas_path_matches_xla(tiny_hg, monkeypatch):
    """HAN's fused path launches the stacked GAT-NA kernel ONCE for the
    whole [P, N, K] metapath stack."""
    _tiny_tables()
    _force_interpret(monkeypatch, "gat_aggregate_stacked")

    cfg_x = HGNNConfig(model="han", dataset="tiny", hidden=16, n_heads=4,
                       n_classes=3, max_degree=48, fused=True, use_pallas=False)
    cfg_p = cfg_x.replace(use_pallas=True)
    m_x, m_p = get_model(cfg_x), get_model(cfg_p)
    b_x, b_p = m_x.prepare(tiny_hg), m_p.prepare(tiny_hg)
    params = m_x.init(jax.random.key(0), b_x)
    lx = m_x.forward(params, b_x)
    lp = m_p.forward(params, b_p)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=3e-4, atol=3e-4)


def test_han_bucketed_pallas_path_matches_xla(tiny_hg, monkeypatch):
    """Degree-bucketed layout + fused kernel vs the plain stacked XLA path."""
    _tiny_tables()
    _force_interpret(monkeypatch, "gat_aggregate")

    cfg_x = HGNNConfig(model="han", dataset="tiny", hidden=16, n_heads=4,
                       n_classes=3, max_degree=48, fused=True)
    cfg_b = cfg_x.replace(degree_buckets=3, use_pallas=True)
    m_x, m_b = get_model(cfg_x), get_model(cfg_b)
    b_x, b_b = m_x.prepare(tiny_hg), m_b.prepare(tiny_hg)
    lx = m_x.forward(m_x.init(jax.random.key(0), b_x), b_x)
    lb = m_b.forward(m_b.init(jax.random.key(0), b_b), b_b)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lb),
                               rtol=3e-4, atol=3e-4)


def test_magnn_pallas_path_matches_xla(tiny_hg, monkeypatch):
    """MAGNN instance attention through the fused GAT-NA kernel (instances
    as the source pool, arange neighbor grid)."""
    _tiny_tables()
    _force_interpret(monkeypatch, "gat_aggregate")

    cfg_x = HGNNConfig(model="magnn", dataset="tiny", hidden=16, n_heads=4,
                       n_classes=3, max_instances=4, use_pallas=False)
    cfg_p = cfg_x.replace(use_pallas=True)
    m_x, m_p = get_model(cfg_x), get_model(cfg_p)
    b_x, b_p = m_x.prepare(tiny_hg), m_p.prepare(tiny_hg)
    params = m_x.init(jax.random.key(0), b_x)
    lx = m_x.forward(params, b_x)
    lp = m_p.forward(params, b_p)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=3e-4, atol=3e-4)


def test_rgcn_pallas_path_matches_xla(tiny_hg, monkeypatch):
    """RGCN's mean NA through the (streaming-capable) segment-SpMM kernel."""
    _tiny_tables()
    _force_interpret(monkeypatch, "segment_spmm")

    cfg_x = HGNNConfig(model="rgcn", dataset="tiny", hidden=16, n_heads=4,
                       n_classes=3, max_degree=48, fused=True)
    cfg_p = cfg_x.replace(use_pallas=True)
    m_x, m_p = get_model(cfg_x), get_model(cfg_p)
    b_x, b_p = m_x.prepare(tiny_hg), m_p.prepare(tiny_hg)
    params = m_x.init(jax.random.key(0), b_x)
    lx = m_x.forward(params, b_x)
    lp = m_p.forward(params, b_p)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=3e-4, atol=3e-4)


def test_semantic_attention_pallas_matches(tiny_hg):
    from repro.core import semantics
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((3, 64, 32)).astype(np.float32))
    p = semantics.init_semantic_attention(jax.random.key(0), 32, 16)
    want = semantics.semantic_attention(p, z)
    got = ops.semantic_attention(z, p["W"], p["b"], p["q"],
                                 use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
