"""Optimizers, schedules, grad accumulation, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)
from repro.train.train_step import init_train_state, make_train_step


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.asarray([[0.5, -0.5]])}


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lambda s: 0.05, weight_decay=0.0),
    lambda: adafactor(lambda s: 0.5),
])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum((x ** 2).sum() for x in jax.tree.leaves(p))

    for i in range(120):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(i))
    assert float(loss(params)) < 0.05 * float(loss(_quadratic_params()))


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < float(lr(jnp.int32(9)))
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 2e-4
    assert float(lr(jnp.int32(99))) < float(lr(jnp.int32(50)))
    assert float(lr(jnp.int32(99))) >= 0.099e-3  # floor


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((2, 2)) * 10.0}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    assert float(gn) > 1.0
    small = {"a": jnp.ones((4,)) * 0.01}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(out["a"], small["a"], rtol=1e-6)


def test_microbatch_accumulation_matches_full_batch(tiny_cfg_base):
    from repro.train.optimizer import build_optimizer

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    opt = build_optimizer(cfg, total_steps=10)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    s1 = init_train_state(jax.random.key(0), cfg, opt)
    s2 = init_train_state(jax.random.key(0), cfg, opt)
    s1, m1 = make_train_step(cfg, opt, n_microbatches=1)(s1, batch)
    s2, m2 = make_train_step(cfg, opt, n_microbatches=2)(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-3, atol=2e-3)


def test_checkpoint_roundtrip(tmp_path, tiny_cfg_base):
    from repro.train.optimizer import build_optimizer

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    opt = build_optimizer(cfg)
    state = init_train_state(jax.random.key(0), cfg, opt)
    d = str(tmp_path / "ck")
    ckpt.save(state, d, step=7)
    assert ckpt.latest_step(d) == 7
    like = jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg, opt))
    restored = ckpt.restore(d, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_corruption_detected(tmp_path, tiny_cfg_base):
    from repro.train.optimizer import build_optimizer

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    opt = build_optimizer(cfg)
    state = init_train_state(jax.random.key(0), cfg, opt)
    d = str(tmp_path / "ck")
    path = ckpt.save(state, d, step=1)
    assert not os.path.exists(path + ".tmp")
    # corrupt the shard -> restore must fail loudly
    shard = os.path.join(path, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        ckpt.restore(d, state)


def test_async_checkpointer(tmp_path, tiny_cfg_base):
    from repro.train.optimizer import build_optimizer

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    opt = build_optimizer(cfg)
    state = init_train_state(jax.random.key(0), cfg, opt)
    d = str(tmp_path / "ck")
    saver = ckpt.AsyncCheckpointer(d)
    saver.submit(state, 1)
    saver.submit(state, 2)
    saver.close()
    assert ckpt.latest_step(d) in (1, 2)  # 1 may be dropped by the 1-deep queue
    restored = ckpt.restore(d, state)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(restored)[0]),
                                  np.asarray(jax.tree.leaves(state)[0]))


def test_data_shard_determinism():
    from repro.train.elastic import data_shard

    a = data_shard(step=12, host_id=3, n_hosts=8, global_batch=256,
                   dataset_size=10_000)
    b = data_shard(step=12, host_id=3, n_hosts=8, global_batch=256,
                   dataset_size=10_000)
    assert a == b
    ranges = [data_shard(5, h, 4, 64, 10_000) for h in range(4)]
    # disjoint per-host ranges covering the global batch
    starts = sorted(r[0] for r in ranges)
    assert len(set(starts)) == 4
    for s, e in ranges:
        assert e - s == 16


def test_step_timer_flags_stragglers():
    from repro.train.elastic import StepTimer

    t = StepTimer(threshold=3.0)
    for _ in range(10):
        assert not t.observe(1.0)
    assert t.observe(10.0)
