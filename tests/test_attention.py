"""Chunked flash attention (fwd + custom_vjp bwd) vs the oracle; rope
properties; decode-attention equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.nn.attention import _chunk_for, chunked_attention
from repro.nn.rope import apply_rope

RNG = np.random.default_rng(1)


def _qkv(b, s, h, kvh, dh, scale=0.5):
    return (jnp.asarray(RNG.standard_normal((b, s, h, dh)) * scale, jnp.float32),
            jnp.asarray(RNG.standard_normal((b, s, kvh, dh)) * scale, jnp.float32),
            jnp.asarray(RNG.standard_normal((b, s, kvh, dh)) * scale, jnp.float32))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
@pytest.mark.parametrize("cq,ck", [(32, 32), (64, 128), (128, 64)])
def test_chunked_forward(causal, window, cq, ck):
    q, k, v = _qkv(2, 128, 4, 2, 32)
    got = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk_q=cq, chunk_k=ck)
    want = ref.mha_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_chunked_backward_matches_reference():
    q, k, v = _qkv(1, 64, 4, 4, 16)

    def f(fn):
        return lambda q, k, v: (fn(q, k, v) ** 2).sum()

    g1 = jax.grad(f(lambda q, k, v: chunked_attention(
        q, k, v, chunk_q=16, chunk_k=16)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(lambda q, k, v: ref.mha_attention(q, k, v)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_cross_attention_lengths():
    """S_q != S_kv (cross attention) works and matches a dense softmax."""
    q = jnp.asarray(RNG.standard_normal((2, 64, 4, 16)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 96, 4, 16)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 96, 4, 16)) * 0.5, jnp.float32)
    got = chunked_attention(q, k, v, causal=False, chunk_q=32, chunk_k=32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / 4.0
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 5000), target=st.integers(1, 512))
def test_chunk_for_divides(s, target):
    c = _chunk_for(s, target)
    assert 1 <= c <= min(target, s) and s % c == 0


def test_rope_relative_property():
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2."""
    q = jnp.asarray(RNG.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(pq, pk):
        qq = apply_rope(q, jnp.asarray([[pq]]), 10000.0)
        kk = apply_rope(k, jnp.asarray([[pk]]), 10000.0)
        return float((qq * kk).sum())

    assert abs(dot_at(5, 3) - dot_at(102, 100)) < 1e-3
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-3


def test_rope_preserves_norm():
    x = jnp.asarray(RNG.standard_normal((2, 8, 4, 16)), jnp.float32)
    y = apply_rope(x, jnp.arange(8), 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_decode_matches_full_attention_last_token():
    q, k, v = _qkv(2, 32, 4, 2, 16)
    full = ref.mha_attention(q, k, v, causal=True)
    dec = ref.decode_attention(q[:, -1], k, v, kv_len=32)
    np.testing.assert_allclose(dec, full[:, -1], rtol=1e-4, atol=1e-4)
