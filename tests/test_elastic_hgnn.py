"""Elastic pod-failure machinery (repro.train.elastic) on the HGNN
partitioned serving path.

``surviving_mesh`` + ``reshard_state`` were built for the LM trainer's
multi-slice restarts; the serving resilience layer reuses them for the
partitioned HGNN arm: when a pod dies, the surviving topology is rebuilt,
the (replicated) model params are device_put onto it, and the engine keeps
serving — with outputs bit-exact vs a never-failed run, since resharding
moves bytes, never values.  Subprocess tests (forced 8-device host mesh) so
the main process keeps its single-device view.
"""
import os
import subprocess
import sys
import textwrap

ENV = {**os.environ, "PYTHONPATH": "src",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}

# Shared preamble: tiny heterograph + partitioned HAN serving engine.
_SETUP = """
    import jax, numpy as np
    import scipy.sparse as sp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs.base import HGNNConfig
    from repro.core.hgraph import HeteroGraph
    from repro.core.models import get_model
    from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
    from repro.serve.engine import HGNNRequest, HGNNServeEngine
    from repro.serve.sampler import HGNNSampler
    from repro.train.elastic import reshard_state, surviving_mesh

    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"
    rng = np.random.default_rng(7)
    counts = {"M": 40, "D": 15, "A": 25}
    dims = {"M": 12, "D": 8, "A": 10}
    feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
             for t, n in counts.items()}

    def rand_rel(ns, nd, e):
        r, c = rng.integers(0, ns, e), rng.integers(0, nd, e)
        return sp.csr_matrix((np.ones(e, np.float32), (r, c)),
                             shape=(ns, nd))

    md, ma = rand_rel(40, 15, 60), rand_rel(40, 25, 80)
    hg = HeteroGraph(counts, feats,
                     {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
                      ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
                     name="tiny")

    cfg = HGNNConfig(model="han", dataset="tiny", hidden=16, n_heads=4,
                     n_classes=3, fanout=64, max_degree=48, fused=True,
                     partitions=2)
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(0), batch)
    sampler = HGNNSampler(m.plan(), cfg, hg)

    def requests(n=6, seed=3):
        r = np.random.default_rng(seed)
        return [HGNNRequest(targets=r.integers(0, 40, size=int(
            r.integers(1, 9)))) for _ in range(n)]

    def serve_logits(ps):
        eng = HGNNServeEngine(m.executor, ps, sampler, slots=2,
                              slot_targets=2,
                              fn=jax.jit(m.executor.forward))
        eng.warmup()
        reqs = requests()
        eng.serve(reqs)
        assert all(r.status == "OK" for r in reqs), [r.status for r in reqs]
        return [r.logits for r in reqs]

    # serving replicates params across pods: every leaf lives on the full
    # mesh so any surviving sub-mesh still holds a complete copy
    def replicated(tree, mesh):
        return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
"""


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c",
                        textwrap.dedent(_SETUP) + textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_pod_loss_reshards_and_serving_stays_bit_exact():
    """(pod=2, data=2, model=2) mesh loses pod 0: surviving_mesh keeps the
    (data, model) sub-grid of pod 1, reshard_state moves the replicated
    HGNN params onto it, and the partitioned serving engine produces
    bit-exact logits on the survivor topology."""
    out = _run("""
        devs = np.array(jax.devices()).reshape(2, 2, 2)
        mesh = Mesh(devs, ("pod", "data", "model"))
        p_full = reshard_state(params, replicated(params, mesh))
        ref = serve_logits(p_full)

        m2 = surviving_mesh(mesh, failed_pods=[0])
        assert m2.axis_names == ("pod", "data", "model") or \\
            m2.axis_names == ("data", "model")
        p_surv = reshard_state(p_full, replicated(params, m2))
        got = serve_logits(p_surv)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)
        print("POD_LOSS_OK", m2.devices.shape)
    """)
    assert "POD_LOSS_OK" in out


def test_single_surviving_pod_collapses_mesh_and_serves():
    """The 1-survivor branch: surviving_mesh drops the 'pod' axis entirely
    (single-pod topology) and the serving path still produces bit-exact
    logits on the collapsed mesh."""
    out = _run("""
        devs = np.array(jax.devices()).reshape(4, 2, 1)
        mesh = Mesh(devs, ("pod", "data", "model"))
        p_full = reshard_state(params, replicated(params, mesh))
        ref = serve_logits(p_full)

        m1 = surviving_mesh(mesh, failed_pods=[0, 1, 3])
        assert m1.axis_names == ("data", "model"), m1.axis_names
        assert m1.devices.shape == (2, 1), m1.devices.shape
        p_one = reshard_state(p_full, replicated(params, m1))
        got = serve_logits(p_one)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)
        # resharding moved bytes, never values
        a0 = np.asarray(jax.tree.leaves(p_full)[0])
        b0 = np.asarray(jax.tree.leaves(p_one)[0])
        np.testing.assert_array_equal(a0, b0)
        print("COLLAPSE_OK")
    """)
    assert "COLLAPSE_OK" in out


def test_surviving_mesh_guards():
    out = _run("""
        devs = np.array(jax.devices()).reshape(2, 2, 2)
        mesh = Mesh(devs, ("pod", "data", "model"))
        try:
            surviving_mesh(mesh, failed_pods=[0, 1])
        except RuntimeError as e:
            assert "no surviving pods" in str(e)
        podless = Mesh(devs.reshape(4, 2), ("data", "model"))
        try:
            surviving_mesh(podless, failed_pods=[0])
        except ValueError as e:
            assert "multi-pod mesh" in str(e)
        print("GUARDS_OK")
    """)
    assert "GUARDS_OK" in out
