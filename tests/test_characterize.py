"""The characterizer itself (the paper's methodology): exact FLOP counts on
known graphs, loop trip-count handling, class attribution, collective bytes,
roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import characterize as ch


def _analyze(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return ch.analyze_hlo_text(comp.as_text())


def test_matmul_flops_exact():
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    rep = _analyze(lambda a, b: a @ b, a, b)
    assert rep["flops_by_class"]["DM"] == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplies():
    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    x = jnp.ones((32, 32), jnp.float32)
    ws = jnp.ones((12, 32, 32), jnp.float32)
    rep = _analyze(f, x, ws)
    assert rep["flops_by_class"]["DM"] == 12 * 2 * 32 * 32 * 32


def test_gather_classified_tb():
    x = jnp.ones((100, 16), jnp.float32)
    idx = jnp.zeros((50,), jnp.int32)
    rep = _analyze(lambda x, i: x[i], x, idx)
    assert rep["op_counts"].get("TB", 0) >= 1


def test_ew_and_dr_classes():
    x = jnp.ones((64, 64), jnp.float32)
    rep = _analyze(lambda x: jnp.tanh(x) + 1.0, x)
    assert rep["flops_by_class"].get("EW", 0) > 0
    rep2 = _analyze(lambda x: jnp.concatenate([x, x], axis=0).T, x)
    assert rep2["hbm_bytes_by_class"].get("DR", 0) > 0 or \
        rep2["hbm_bytes_by_class"].get("EW", 0) > 0


def test_shape_bytes_parsing():
    assert ch.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert ch.shape_bytes("bf16[2,3,4]") == 48
    assert ch.shape_bytes("(s32[], f32[8]{0})") == 4 + 32
    assert ch.shape_bytes("pred[]") == 1
    assert ch.shape_bytes("token[]") == 0


def test_roofline_terms_and_bound():
    per_dev = {"total_flops": 197e12, "total_hbm_bytes": 819e9 / 2,
               "collective_bytes": 0.0}
    r = ch.roofline(per_dev, n_chips=1, model_fl=197e12)
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 0.5) < 1e-6
    assert r["bound"] == "compute"
    assert abs(r["mfu_proxy"] - 1.0) < 1e-6
    per_dev["collective_bytes"] = 50e9 * 3
    r = ch.roofline(per_dev, n_chips=1, model_fl=197e12)
    assert r["bound"] == "collective"


def test_model_flops_formulas():
    from repro.configs import get_config
    from repro.configs.base import SHAPES

    cfg = get_config("granite-8b")
    n_total, n_active = ch.analytic_param_counts(cfg)
    assert n_total == n_active  # dense
    mf_train = ch.model_flops(cfg, SHAPES["train_4k"], n_total, n_active)
    assert abs(mf_train - 6 * n_total * 256 * 4096) / mf_train < 1e-9
    mf_dec = ch.model_flops(cfg, SHAPES["decode_32k"], n_total, n_active)
    assert abs(mf_dec - 2 * n_total * 128) / mf_dec < 1e-9


def test_moe_active_params_fraction():
    from repro.configs import get_config

    cfg = get_config("phi3.5-moe-42b-a6.6b")
    total, active = ch.analytic_param_counts(cfg)
    assert active < 0.35 * total  # top-2 of 16 experts + attention


def test_collective_bytes_sharded_matmul():
    """All-gather bytes appear for a TP matmul on a small forced-device run."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import characterize as ch
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        w_s = NamedSharding(mesh, P(None, "model"))
        x_s = NamedSharding(mesh, P("data", None))
        f = jax.jit(lambda x, w: (x @ w).sum(), in_shardings=(x_s, w_s))
        c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        rep = ch.analyze_hlo_text(c.as_text())
        assert rep["collective_bytes"] > 0, rep
        print("OK", rep["collective_bytes"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                       "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stdout + r.stderr
