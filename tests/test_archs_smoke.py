"""Per-architecture smoke tests: every assigned arch instantiates in its
REDUCED config and runs one forward + one train step on CPU, asserting
output shapes and finite values (the brief's requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.configs.base import ShapeConfig
from repro.data.loader import synth_batch
from repro.train.optimizer import build_optimizer
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list_archs()
SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, SMOKE_SHAPE, step=0).items()}
    if cfg.family == "encdec":
        from repro.nn.encdec import encdec_forward, init_encdec_params

        params = init_encdec_params(jax.random.key(0), cfg)
        logits = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    else:
        from repro.nn.transformer import init_lm_params, lm_forward

        params = init_lm_params(jax.random.key(0), cfg)
        logits, _ = lm_forward(params, cfg, batch["tokens"],
                               batch.get("extra_embeds"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    opt = build_optimizer(cfg, total_steps=10)
    step = make_train_step(cfg, opt)
    state = init_train_state(jax.random.key(0), cfg, opt)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, SMOKE_SHAPE, step=0).items()}
    state, metrics = jax.jit(step)(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    leaf0 = jax.tree.leaves(state.params)[0]
    assert bool(jnp.isfinite(leaf0).all())


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-2.7b", "zamba2-1.2b",
                                  "h2o-danube-3-4b"])
def test_loss_decreases_on_fixed_batch(arch):
    """A few steps on one repeated batch must reduce loss (overfit sanity)."""
    cfg = get_reduced(arch)
    opt = build_optimizer(cfg, total_steps=30)
    step = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(jax.random.key(0), cfg, opt)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, ShapeConfig("s", 16, 2, "train"), step=0).items()}
    first = None
    for _ in range(8):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first, (arch, first, float(m["loss"]))


def test_full_configs_match_assignment():
    """Exact architecture hyperparameters from the assignment table."""
    from repro.configs import get_config

    c = get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (35, 7168, 56, 8, 4864, 32000)
    assert c.moe.n_experts == 128 and c.moe.top_k == 2 \
        and c.moe.dense_residual_ff == 4864
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k, c.vocab) \
        == (32, 4096, 16, 2, 32064)
    c = get_config("internvl2-76b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (80, 8192, 64, 8, 28672, 128256)
    c = get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state, c.vocab) == (64, 2560, 128, 50280)
    c = get_config("granite-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (36, 4096, 32, 8, 14336, 49152)
    c = get_config("smollm-360m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (32, 960, 15, 5, 2560, 49152)
    c = get_config("h2o-danube-3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (24, 3840, 32, 8, 10240, 32000)
    assert c.sliding_window > 0
    c = get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (32, 4096, 32, 32, 13440, 92416)
    c = get_config("seamless-m4t-medium")
    assert (c.enc_layers, c.dec_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) \
        == (12, 12, 1024, 16, 4096, 256206)
    c = get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab,
            c.ssm.d_state) == (38, 2048, 32, 8192, 32000, 64)


def test_param_counts_in_expected_range():
    """Analytic param counts are within 25% of the advertised sizes."""
    from repro.configs import get_config
    from repro.core.characterize import analytic_param_counts

    for arch, lo, hi in [("arctic-480b", 360e9, 600e9),
                         ("internvl2-76b", 57e9, 95e9),
                         ("granite-8b", 6e9, 10e9),
                         ("mamba2-2.7b", 2.0e9, 3.4e9),
                         ("smollm-360m", 0.27e9, 0.45e9),
                         ("zamba2-1.2b", 0.9e9, 1.6e9)]:
        total, active = analytic_param_counts(get_config(arch))
        assert lo <= total <= hi, (arch, total)
        assert active <= total
