"""Async stage-graph pipelining (ScheduleSpec / forward_overlapped).

The tentpole invariant: every overlapped execution mode is BIT-EXACT the
serial schedule.  The split points are pure row selections (owned-rows
gather + halo where-merge) and elementwise rearrangements (stack-after-act),
never float reductions, so `np.testing.assert_array_equal` — not allclose —
is the bar across the whole matrix: HAN/RGCN/MAGNN, 1 and 2 layers,
partitioned K=4, and sampled serving with the prefetch thread.

Also pinned here: depth=1 degrades to fully-blocking serial dispatch;
single-metapath plans skip the metapath fan-out (nothing to overlap); the
plan-derived DAG and its concurrency counters; static partition shapes
(the serving re-trace fix) are bit-exact vs the dynamic minimal shapes;
and the sampler prefetcher drains cleanly through deadline expiry and
partition failover.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
from repro.dist.partition import partition_batch
from repro.serve.engine import HGNNRequest, HGNNServeEngine
from repro.serve.resilience import OK, PARTIAL, ResilienceConfig
from repro.serve.sampler import HGNNSampler


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"
    # single-metapath registration for the no-fan-out edge case
    DATASET_METAPATHS["tiny1"] = [["M", "D", "M"]]
    DATASET_TARGET["tiny1"] = "M"


def _cfg(model, dataset="tiny", **kw):
    _tiny_tables()
    kw = {"max_degree": 48, "max_instances": 4, "fused": True, **kw}
    return HGNNConfig(model=model, dataset=dataset, hidden=16, n_heads=4,
                      n_classes=3, **kw)


def _forward_pair(tiny_hg, model, kw, overlap):
    """(model, serial forward, overlapped forward) at the given depth."""
    cfg = _cfg(model, overlap=overlap, **kw)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    ref = np.asarray(jax.jit(m.forward)(params, batch))
    out = np.asarray(m.forward_overlapped(params, batch))
    return m, ref, out


# ---------------------------------------------------------------------------
# the parity matrix: overlapped == serial, bitwise
# ---------------------------------------------------------------------------

MATRIX = [
    ("han", {}),                               # stacked: single NA launch
    ("han", {"degree_buckets": 3}),            # bucketed: metapath fan-out
    ("han", {"fused": False}),                 # csr: metapath fan-out
    ("rgcn", {}),
    ("magnn", {}),                             # instances: metapath fan-out
    ("han", {"layers": 2}),
    ("rgcn", {"layers": 2}),
    ("magnn", {"layers": 2}),
    ("han", {"partitions": 4}),                # halo/compute split
    ("rgcn", {"partitions": 4}),
    ("magnn", {"partitions": 4}),
    ("han", {"partitions": 4, "layers": 2}),
    ("rgcn", {"partitions": 4, "layers": 2}),
]


@pytest.mark.parametrize("model,kw", MATRIX,
                         ids=[f"{m}-{'-'.join(f'{k}{v}' for k, v in kw.items()) or 'base'}"
                              for m, kw in MATRIX])
def test_overlapped_forward_is_bitexact(tiny_hg, model, kw):
    m, ref, out = _forward_pair(tiny_hg, model, kw, overlap=2)
    np.testing.assert_array_equal(ref, out)
    # the dispatcher walked exactly the declared DAG
    d = m.executor.last_dispatch
    rec = m.executor.overlap_record()
    assert d["depth"] == 2
    assert len(d["dispatched"]) == rec["stages"]
    assert list(m.executor.schedule_edges()) == d["dispatched"]


def test_depth_one_degrades_to_serial(tiny_hg):
    """overlap=1 is the serial-degenerate baseline: every admit blocks, so
    at most one stage result is ever in flight — and the math is still the
    same stage functions, so outputs stay bitwise equal."""
    for model, kw in [("han", {"degree_buckets": 3}),
                      ("rgcn", {"partitions": 4, "layers": 2})]:
        m, ref, out = _forward_pair(tiny_hg, model, kw, overlap=1)
        np.testing.assert_array_equal(ref, out)
        assert m.executor.last_dispatch["max_inflight"] == 1


def test_repeated_overlapped_calls_reuse_stage_jits(tiny_hg):
    m, _, out1 = _forward_pair(tiny_hg, "han", {"partitions": 4}, overlap=2)
    n_jits = len(m.executor._ov_jit)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    out2 = np.asarray(m.forward_overlapped(params, batch))
    np.testing.assert_array_equal(out1, out2)
    assert len(m.executor._ov_jit) == n_jits  # no new traces


# ---------------------------------------------------------------------------
# the plan-derived DAG
# ---------------------------------------------------------------------------


def test_schedule_edges_partitioned_split(tiny_hg):
    m = get_model(_cfg("han", partitions=4, layers=2, overlap=2))
    edges = m.executor.schedule_edges()
    assert edges["L1.gather_halo"] == ("L1.FP",)
    assert edges["L1.NA.own"] == ("L1.FP",)
    assert edges["L1.NA"] == ("L1.NA.own", "L1.gather_halo")
    assert edges["L2.FP"] == ("L1.SA",)
    rec = m.executor.overlap_record()
    assert rec["concurrent_pairs"] == 2
    assert "L1.gather_halo|L1.NA.own" in rec["pairs"]
    assert "L2.gather_halo|L2.NA.own" in rec["pairs"]


def test_schedule_edges_metapath_split(tiny_hg):
    m = get_model(_cfg("han", degree_buckets=3, overlap=2))
    edges = m.executor.schedule_edges()
    assert edges["NA.p0"] == ("FP",)
    assert edges["NA.p1"] == ("FP",)
    assert edges["SA"] == ("NA.p0", "NA.p1")
    assert m.executor.overlap_record()["concurrent_pairs"] == 1


def test_single_metapath_plan_skips_metapath_concurrency(tiny_hg):
    """One metapath has nothing to overlap: the schedule must fall back to
    the serial chain (no NA.p nodes, zero concurrent pairs) — and still run
    bit-exact through the overlapped dispatcher."""
    m, ref, out = _forward_pair(tiny_hg, "han",
                                {"dataset": "tiny1", "degree_buckets": 3},
                                overlap=2)
    np.testing.assert_array_equal(ref, out)
    edges = m.executor.schedule_edges()
    assert "NA" in edges and not any(n.startswith("NA.p") for n in edges)
    assert m.executor.overlap_record()["concurrent_pairs"] == 0


def test_stacked_layout_keeps_single_na_launch(tiny_hg):
    """HAN's stacked layout is ONE batched launch by design — the schedule
    must not fan it out into per-metapath stages."""
    m = get_model(_cfg("han", overlap=2))  # fused=True -> stacked
    edges = m.executor.schedule_edges()
    assert "NA" in edges and not any(n.startswith("NA.p") for n in edges)


def test_stage_records_carry_overlap_record(tiny_hg):
    cfg = _cfg("han", degree_buckets=3, overlap=2)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    recs = m.executor.stage_records(params, batch)
    assert recs["overlap"]["concurrent_pairs"] == 1
    # serial default plans grow no overlap section
    m0 = get_model(_cfg("han", degree_buckets=3))
    b0 = m0.prepare(tiny_hg)
    p0 = m0.init(jax.random.key(0), b0)
    assert "overlap" not in m0.executor.stage_records(p0, b0)


# ---------------------------------------------------------------------------
# the dispatch window + the accounting
# ---------------------------------------------------------------------------


def test_inflight_window_depth_semantics():
    from repro.kernels.streaming import InflightWindow

    win = InflightWindow(0)  # clamps to the serial baseline
    assert win.depth == 1
    win = InflightWindow(2)
    for i in range(5):
        win.admit(f"s{i}", jnp.ones(4) * i)
    # admit-then-block: the window holds depth results plus the one being
    # admitted before it blocks on the oldest
    assert win.max_inflight == 3
    win.drain()
    assert win._live == []
    assert win.admitted == [f"s{i}" for i in range(5)]


def test_overlap_accounting_critical_path():
    from repro.core.characterize import overlap_accounting

    edges = {"FP": (), "gather_halo": ("FP",), "NA.own": ("FP",),
             "NA": ("NA.own", "gather_halo"), "SA": ("NA",), "head": ("SA",)}
    walls = {"FP": 10.0, "gather_halo": 5.0, "NA.own": 20.0, "NA": 30.0,
             "SA": 5.0, "head": 1.0}
    acct = overlap_accounting(edges, walls)
    assert acct["serial_sum_us"] == 71.0
    # the 5us exchange hides entirely behind the 20us owned-rows NA
    assert acct["critical_path_us"] == 66.0
    assert acct["overlap_saved_us"] == 5.0
    assert acct["exposure_us"]["gather_halo"] == 0.0
    # zeroing NA.own leaves the exchange path (10+5) feeding NA
    assert acct["exposure_us"]["NA.own"] == 15.0
    assert acct["exposure_us"]["FP"] == 10.0


# ---------------------------------------------------------------------------
# static partition shapes (the serving re-trace fix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model,kw", [
    ("han", {}), ("rgcn", {}), ("magnn", {}), ("rgcn", {"layers": 2}),
    ("han", {"layers": 2}),
], ids=["han", "rgcn", "magnn", "rgcn-L2", "han-L2"])
def test_static_partition_shapes_are_bitexact(tiny_hg, model, kw):
    """static_shapes pads every per-type table to assignment-independent
    capacities (n_max=ceil(n/k), h_max=n).  Pad rows are masked dead weight:
    the forward over the padded batch must be BIT-EXACT the dynamic one."""
    cfg = _cfg(model, partitions=4, **kw)
    m = get_model(cfg)
    b_dyn = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), b_dyn)
    out_dyn = np.asarray(jax.jit(m.forward)(params, b_dyn))
    plan = m.plan()
    plan_s = dataclasses.replace(
        plan, partition=dataclasses.replace(plan.partition,
                                            static_shapes=True))
    b_raw = get_model(_cfg(model, **kw)).prepare(tiny_hg)
    b_stat = partition_batch(plan_s, b_raw)
    out_stat = np.asarray(jax.jit(m.forward)(params, b_stat))
    np.testing.assert_array_equal(out_dyn, out_stat)
    # the capacities are assignment-independent: ceil(40/4) target rows
    # per partition, halo capped at the type count
    t = plan.target
    assert b_stat["part"]["own"][t].shape == (4, 10)
    assert b_stat["part"]["halo_src"][t].shape == (4, 40)


def test_partitioned_sampled_serving_zero_recompiles(tiny_hg):
    """The satellite regression: partitioned sampled serving used to
    re-trace every step (data-dependent halo widths).  With the engine's
    static_shapes serve plan the warmed ladder covers every step."""
    for model in ("han", "rgcn", "magnn"):
        cfg = _cfg(model, fanout=8, partitions=4)
        m = get_model(cfg)
        sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
        batch = m.prepare(tiny_hg)
        params = m.init(jax.random.key(0), batch)
        eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                              slot_targets=2)
        eng.warmup()
        eng.serve(_mixed_requests(10))
        st = eng.stats()
        assert st["steps"] > 1
        assert st["compiles_after_warmup"] == 0, model


# ---------------------------------------------------------------------------
# sampled serving: async prefetch parity + drain discipline
# ---------------------------------------------------------------------------


def _mixed_requests(n, n_nodes=40, seed=3):
    rng = np.random.default_rng(seed)
    return [HGNNRequest(targets=rng.integers(
        0, n_nodes, size=int(rng.integers(1, 9)))) for _ in range(n)]


def _serve(tiny_hg, model, overlap, partitions=0, res=None, injector=None,
           n_req=10):
    cfg = _cfg(model, fanout=8, partitions=partitions, overlap=overlap)
    m = get_model(cfg)
    sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                          slot_targets=2, resilience_cfg=res,
                          injector=injector)
    eng.warmup()
    reqs = eng.serve(_mixed_requests(n_req))
    return reqs, eng


@pytest.mark.parametrize("model,partitions", [
    ("han", 0), ("rgcn", 0), ("magnn", 0),
    ("han", 4), ("rgcn", 4), ("magnn", 4),
], ids=["han", "rgcn", "magnn", "han-k4", "rgcn-k4", "magnn-k4"])
def test_serving_prefetch_is_bitexact(tiny_hg, model, partitions):
    """The prefetch thread must change walls only: statuses and logits are
    bitwise identical to the synchronous serve, the jit cache stays warm,
    and most steps hit the speculation (the slot loop is predictable)."""
    r_sync, e_sync = _serve(tiny_hg, model, overlap=0, partitions=partitions)
    r_pf, e_pf = _serve(tiny_hg, model, overlap=2, partitions=partitions)
    assert e_sync.prefetch is None and e_pf.prefetch is not None
    for a, b in zip(r_sync, r_pf):
        assert a.status == b.status
        np.testing.assert_array_equal(a.logits, b.logits)
    st = e_pf.stats()
    assert st["compiles_after_warmup"] == 0
    pf = st["prefetch"]
    assert pf["hits"] > 0 and pf["cold"] == 1
    assert pf["hits"] + pf["mispredicts"] + pf["cold"] == st["steps"]


def test_prefetch_drains_on_deadline_expiry(tiny_hg):
    """Every request expires before a step runs: the loop ends without ever
    consuming a speculation, and the worker must still shut down clean."""
    reqs, eng = _serve(tiny_hg, "han", overlap=2,
                       res=ResilienceConfig(deadline_ms=0.0), n_req=5)
    assert all(r.status == PARTIAL for r in reqs)
    assert eng.prefetch._future is None
    assert eng.prefetch._pool._shutdown


def test_prefetch_drains_through_partition_failover(tiny_hg):
    """Failover mid-serve: the sampler is partition-agnostic, so in-flight
    speculation stays valid across the spec swap; requests still complete
    OK and the worker shuts down clean."""
    from repro.serve.faults import Fault, FaultInjector

    inj = FaultInjector([Fault(step=1, kind="partition", partition=2)])
    reqs, eng = _serve(tiny_hg, "han", overlap=2, partitions=4, injector=inj)
    assert all(r.status == OK for r in reqs)
    rs = eng.stats()["resilience"]
    assert rs["partition_failovers"] == 1 and rs["lost_partitions"] == [2]
    assert eng.stats()["prefetch"]["issued"] > 0
    assert eng.prefetch._future is None
    assert eng.prefetch._pool._shutdown
