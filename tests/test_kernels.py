"""Per-kernel validation: Pallas interpret=True vs the ref.py oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_fp_na import fused_fp_na
from repro.kernels.segment_spmm import segment_spmm
from repro.kernels.semantic_attn import semantic_attention

RNG = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("n,m,k,d", [(17, 23, 5, 8), (128, 64, 16, 64),
                                     (257, 300, 9, 33)])
@pytest.mark.parametrize("mean", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm(n, m, k, d, mean, dtype):
    h = _arr((m, d), dtype)
    nbr = jnp.asarray(RNG.integers(0, m, (n, k)), jnp.int32)
    mask = jnp.asarray(RNG.random((n, k)) < 0.7, jnp.float32)
    got = segment_spmm(h, nbr, mask, mean=mean, interpret=True, block_n=64)
    want = ref.segment_spmm(h, nbr, mask, mean=mean)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,m,k,f,d,bf", [(33, 50, 4, 20, 16, 8),
                                          (100, 80, 8, 70, 32, 32)])
def test_fused_fp_na(n, m, k, f, d, bf):
    x = _arr((m, f))
    w = _arr((f, d))
    nbr = jnp.asarray(RNG.integers(0, m, (n, k)), jnp.int32)
    mask = jnp.asarray(RNG.random((n, k)) < 0.8, jnp.float32)
    got = fused_fp_na(x, w, nbr, mask, interpret=True, block_n=32, block_f=bf)
    want = ref.fused_fp_na(x, w, nbr, mask)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("p,n,d,hs", [(2, 50, 16, 8), (5, 130, 32, 16)])
def test_semantic_attention(p, n, d, hs):
    z = _arr((p, n, d))
    w, b, q = _arr((d, hs)), _arr((hs,)), _arr((hs,))
    got = semantic_attention(z, w, b, q, block_n=32, interpret=True)
    want = ref.semantic_attention(z, w, b, q)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,h,kvh,dh,bq,bk", [(128, 4, 2, 32, 32, 32),
                                              (256, 8, 8, 16, 64, 128),
                                              (128, 6, 2, 64, 128, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 40)])
def test_flash_attention(s, h, kvh, dh, bq, bk, causal, window):
    q = _arr((2, s, h, dh), scale=0.5)
    k = _arr((2, s, kvh, dh), scale=0.5)
    v = _arr((2, s, kvh, dh), scale=0.5)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.mha_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = _arr((1, 128, 4, 32), dtype, 0.5)
    k = _arr((1, 128, 2, 32), dtype, 0.5)
    v = _arr((1, 128, 2, 32), dtype, 0.5)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.mha_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("b,s,h,kvh,dh,bk", [(2, 128, 4, 2, 32, 32),
                                             (3, 256, 8, 8, 16, 128)])
def test_decode_attention(b, s, h, kvh, dh, bk):
    q = _arr((b, h, dh), scale=0.5)
    k = _arr((b, s, kvh, dh), scale=0.5)
    v = _arr((b, s, kvh, dh), scale=0.5)
    kv_len = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    got = decode_attention(q, k, v, kv_len, block_k=bk, interpret=True)
    want = ref.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_gat_aggregate_matches_stages():
    from repro.core import stages
    from repro.kernels import ops

    n, k, h, dh = 60, 7, 4, 16
    hsrc = _arr((n, h, dh))
    nbr = jnp.asarray(RNG.integers(0, n, (n, k)), jnp.int32)
    mask = jnp.asarray(RNG.random((n, k)) < 0.8, jnp.float32)
    p = {"a_dst": _arr((h, dh), scale=0.2), "a_src": _arr((h, dh), scale=0.2)}
    want = stages.gat_aggregate_padded(p, hsrc, hsrc, nbr, mask)
    got = ops.gat_aggregate(p, hsrc, hsrc, nbr, mask, use_pallas=True,
                            interpret=True)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
