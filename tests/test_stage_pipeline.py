"""Stage-graph executor: the parity matrix.

Every model runs through the one executor (core/pipeline.py) across the
execution modes the plan can express — {baseline, fused, bucketed,
streaming, sharded-8dev, fused NA→SA epilogue} — and must match the seed
reference path.  Also pins: plan-layout resolution, the RGCN bucketed-mean
dispatch, and that per-stage characterization records sum to the
whole-model totals.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp, stages
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"


def _cfg(model, **kw):
    _tiny_tables()
    kw = {"max_degree": 48, "max_instances": 4, **kw}
    return HGNNConfig(model=model, dataset="tiny", hidden=16, n_heads=4,
                      n_classes=3, **kw)


def _forward(cfg, hg, params=None):
    m = get_model(cfg)
    batch = m.prepare(hg)
    if params is None:
        params = m.init(jax.random.key(0), batch)
    return m, params, np.asarray(m.forward(params, batch))


def _force_interpret(monkeypatch, name):
    """Force an ops wrapper onto the Pallas path in interpret mode."""
    from repro.kernels import ops

    orig = getattr(ops, name)
    monkeypatch.setattr(
        ops, name,
        lambda *args, use_pallas=False, interpret=False, **kw:
        orig(*args, use_pallas=True, interpret=True, **kw))


def _force_streaming(monkeypatch, name):
    """Route an ops wrapper straight into the streaming kernel (small chunk
    size so the double-buffered DMA path genuinely runs)."""
    from repro.kernels import gat_na as gmod, segment_spmm as smod, ops

    if name == "gat_aggregate_stacked":
        monkeypatch.setattr(
            ops, name,
            lambda p, hd, hs, nn, mm, **kw: gmod.gat_na(
                p, hd, hs, nn, mm, block_n=16, block_m=8, interpret=True))
    elif name == "gat_aggregate_stacked_fused_sa":
        monkeypatch.setattr(
            ops, name,
            lambda p, hd, hs, nn, mm, sem, **kw: gmod.gat_na(
                p, hd, hs, nn, mm, block_n=16, block_m=8, interpret=True,
                sem=sem))
    elif name == "segment_spmm":
        monkeypatch.setattr(
            ops, name,
            lambda hs, nn, mm, mean=True, **kw: smod.segment_spmm(
                hs, nn, mm, mean=mean, block_n=16, block_m=8, interpret=True))


# ---------------------------------------------------------------------------
# the parity matrix
# ---------------------------------------------------------------------------

MATRIX = [
    # (model, reference kwargs, variant kwargs, ops wrapper to force, mode)
    ("han", {"fused": False}, {"fused": True}, None, None),
    ("han", {"fused": True}, {"fused": True, "degree_buckets": 3},
     None, None),
    ("han", {"fused": True}, {"fused": True, "use_pallas": True},
     "gat_aggregate_stacked", "interpret"),
    ("han", {"fused": True}, {"fused": True, "use_pallas": True},
     "gat_aggregate_stacked", "streaming"),
    ("han", {"fused": True}, {"fused": True, "fuse_na_sa": True},
     None, None),
    ("han", {"fused": True},
     {"fused": True, "fuse_na_sa": True, "use_pallas": True},
     "gat_aggregate_stacked_fused_sa", "interpret"),
    ("han", {"fused": True},
     {"fused": True, "fuse_na_sa": True, "use_pallas": True},
     "gat_aggregate_stacked_fused_sa", "streaming"),
    ("rgcn", {"fused": False}, {"fused": True}, None, None),
    ("rgcn", {"fused": True}, {"fused": True, "degree_buckets": 3},
     None, None),
    ("rgcn", {"fused": True}, {"fused": True, "use_pallas": True},
     "segment_spmm", "streaming"),
    ("rgcn", {"fused": True},
     {"fused": True, "degree_buckets": 3, "use_pallas": True},
     "segment_spmm", "interpret"),
    ("magnn", {}, {"use_pallas": True}, "gat_aggregate", "interpret"),
    # graph-partitioned execution (repro.dist.partition): K=1 exercises the
    # machinery with empty halos, K=4 the real halo exchange
    ("han", {"fused": True}, {"fused": True, "partitions": 1}, None, None),
    ("han", {"fused": True}, {"fused": True, "partitions": 4}, None, None),
    ("rgcn", {"fused": True}, {"fused": True, "partitions": 4}, None, None),
    ("magnn", {}, {"partitions": 1}, None, None),
    ("magnn", {}, {"partitions": 4}, None, None),
    # multi-layer stacks (L=2): every layout pair must agree at depth, and
    # the partitioned flow (per-layer halo re-exchange over the
    # graph-invariant maps) must match the unpartitioned L=2 forward
    ("han", {"fused": False, "layers": 2}, {"fused": True, "layers": 2},
     None, None),
    ("han", {"fused": True, "layers": 2},
     {"fused": True, "layers": 2, "degree_buckets": 3}, None, None),
    ("han", {"fused": True, "layers": 2},
     {"fused": True, "layers": 2, "fuse_na_sa": True}, None, None),
    ("han", {"fused": True, "layers": 2},
     {"fused": True, "layers": 2, "partitions": 4}, None, None),
    ("rgcn", {"fused": False, "layers": 2}, {"fused": True, "layers": 2},
     None, None),
    ("rgcn", {"fused": True, "layers": 2},
     {"fused": True, "layers": 2, "degree_buckets": 3}, None, None),
    ("rgcn", {"fused": True, "layers": 2},
     {"fused": True, "layers": 2, "partitions": 4}, None, None),
    ("magnn", {"layers": 2}, {"layers": 2, "partitions": 4}, None, None),
]


@pytest.mark.parametrize(
    "model,ref_kw,var_kw,wrapper,mode", MATRIX,
    ids=[f"{m}-{'_'.join(f'{k}{v}' for k, v in v_kw.items())}-{md or 'xla'}"
         for m, _, v_kw, _, md in MATRIX])
def test_executor_parity_matrix(tiny_hg, monkeypatch, model, ref_kw, var_kw,
                                wrapper, mode):
    cfg_ref = _cfg(model, **ref_kw)
    _, params, want = _forward(cfg_ref, tiny_hg)
    if wrapper is not None:
        (_force_streaming if mode == "streaming"
         else _force_interpret)(monkeypatch, wrapper)
    cfg_var = _cfg(model, **var_kw)
    m_var = get_model(cfg_var)
    b_var = m_var.prepare(tiny_hg)
    # same init key: identical params modulo layout (stacking / lists)
    p_var = m_var.init(jax.random.key(0), b_var)
    got = np.asarray(m_var.forward(p_var, b_var))
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_gcn_runs_through_executor():
    from repro.data.synthetic import make_reddit_like

    hg = make_reddit_like(scale=0.005)
    cfg = HGNNConfig(model="gcn", dataset="reddit", hidden=16, n_classes=5)
    m, params, out = _forward(cfg, hg)
    assert m.plan().na.kind == "gcn" and m.plan().sa.kind == "none"
    assert out.shape[1] == 5 and np.isfinite(out).all()


def test_gcn_two_layer_matches_manual_block_composition():
    """GCN depth semantics pinned by hand: one LayerPlan is one
    agg(relu(agg(h @ w))) block, L=2 stacks two blocks before the head."""
    from repro.data.synthetic import make_reddit_like

    hg = make_reddit_like(scale=0.005)
    cfg = HGNNConfig(model="gcn", dataset="reddit", hidden=16, n_classes=5,
                     layers=2)
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(0), batch)
    got = np.asarray(m.forward(params, batch))

    def block(h, w):
        h = h @ w
        z = jax.nn.relu(stages.mean_aggregate_csr(
            h, batch["seg"], batch["idx"], h.shape[0]))
        return stages.mean_aggregate_csr(z, batch["seg"], batch["idx"],
                                         z.shape[0])

    want = block(block(batch["x"], params["w1"]),
                 params["layers"][0]["fp"]) @ params["w2"]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-6, atol=1e-6)


def test_multilayer_forward_differs_from_single_layer(tiny_hg):
    """A second layer must actually change the output (no silent L=1
    fallthrough) while keeping shapes and finiteness."""
    for model, kw in [("han", {"fused": True}), ("rgcn", {"fused": True}),
                      ("magnn", {})]:
        _, _, one = _forward(_cfg(model, **kw), tiny_hg)
        _, _, two = _forward(_cfg(model, layers=2, **kw), tiny_hg)
        assert one.shape == two.shape
        assert np.isfinite(two).all()
        assert np.abs(one - two).max() > 1e-6, model


def test_multilayer_stage_records_per_layer(tiny_hg):
    """The acceptance invariant: an L-layer run's stage_records carries
    per-layer ``L{i}.FP/NA/SA`` whose sums reconcile with the end-to-end
    totals; partitioned runs add per-layer ``L{i}.gather_halo`` records and
    the partition summary reports halo-bytes × L."""
    cfg = _cfg("han", fused=True, layers=2)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    recs = m.stage_records(params, batch)
    assert set(recs["stages"]) == {
        "L1.FP", "L1.NA", "L1.SA", "L2.FP", "L2.NA", "L2.SA", "head"}
    for name, r in recs["stages"].items():
        assert r["flops"] > 0 and r["hbm_bytes"] > 0, name
    assert recs["total"]["flops"] == pytest.approx(
        sum(r["flops"] for r in recs["stages"].values()))
    assert recs["total"]["hbm_bytes"] == pytest.approx(
        sum(r["hbm_bytes"] for r in recs["stages"].values()))

    cfg_p = _cfg("han", fused=True, layers=2, partitions=3)
    m = get_model(cfg_p)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    recs = m.stage_records(params, batch)
    assert {"L1.gather_halo", "L2.gather_halo"} <= set(recs["stages"])
    pt = recs["partition"]
    assert pt["layers"] == 2
    gh_sum = (recs["stages"]["L1.gather_halo"]["halo_bytes"]
              + recs["stages"]["L2.gather_halo"]["halo_bytes"])
    assert pt["halo_bytes_total"] == pytest.approx(gh_sum)
    assert pt["halo_bytes_total"] == pytest.approx(2 * pt["halo_bytes"])
    assert pt["halo_bytes"] > 0


def test_multilayer_params_layout(tiny_hg):
    """Layer 0 stays at the pytree root (bit-exact single-layer layout);
    hidden layers ride params["layers"] with mirrored leaf names, and the
    same init key yields identical layer-0 leaves for L=1 and L=2."""
    cfg1 = _cfg("han", fused=True)
    m1 = get_model(cfg1)
    b1 = m1.prepare(tiny_hg)
    p1 = m1.init(jax.random.key(0), b1)
    cfg2 = _cfg("han", fused=True, layers=2)
    m2 = get_model(cfg2)
    b2 = m2.prepare(tiny_hg)
    p2 = m2.init(jax.random.key(0), b2)
    assert set(p2) == set(p1) | {"layers"}
    for leaf1, leaf2 in zip(jax.tree.leaves(p1),
                            jax.tree.leaves({k: v for k, v in p2.items()
                                             if k != "layers"})):
        np.testing.assert_array_equal(np.asarray(leaf1), np.asarray(leaf2))
    hidden = p2["layers"][0]
    assert {"fp", "gat", "sem"} <= set(hidden)
    assert hidden["fp"].shape == (cfg2.hidden, cfg2.hidden)


def test_executor_sharded_8dev_matches_single_device(tiny_hg):
    """{HAN stacked, HAN bucketed, RGCN bucketed, MAGNN} through
    build_hgnn_infer on a forced 2x4 host mesh == unsharded forward."""
    code = textwrap.dedent("""
        import numpy as np, scipy.sparse as sp, jax
        from repro.configs.base import HGNNConfig
        from repro.core.hgraph import HeteroGraph
        from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.serve import build_hgnn_infer

        rng = np.random.default_rng(7)
        counts = {"M": 40, "D": 15, "A": 25}
        dims = {"M": 12, "D": 8, "A": 10}
        feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
                 for t, n in counts.items()}
        def rr(ns, nd, e):
            r = rng.integers(0, ns, e); c = rng.integers(0, nd, e)
            return sp.csr_matrix((np.ones(e, np.float32), (r, c)),
                                 shape=(ns, nd))
        md, ma = rr(40, 15, 60), rr(40, 25, 80)
        hg = HeteroGraph(counts, feats,
                         {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
                          ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
                         name="tiny")
        DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
        DATASET_TARGET["tiny"] = "M"

        mesh = make_smoke_mesh(data=2, model=4)
        cases = [
            dict(model="han", fused=True),
            dict(model="han", fused=True, degree_buckets=3),
            dict(model="han", fused=True, layers=2),
            dict(model="rgcn", fused=True, degree_buckets=3),
            dict(model="magnn"),
        ]
        for kw in cases:
            cfg = HGNNConfig(dataset="tiny", hidden=16, n_heads=4,
                             n_classes=3, max_degree=12, max_instances=4, **kw)
            built = build_hgnn_infer(cfg, hg, mesh)
            sharded = np.asarray(built.fn(built.params, built.batch))
            ref = build_hgnn_infer(cfg, hg)  # single-device, same plan
            plain = np.asarray(ref.fn(ref.params, ref.batch))
            np.testing.assert_allclose(sharded, plain, rtol=2e-4, atol=2e-4)
            print("OK", kw)
    """)
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("OK") == 5


def test_partitioned_8dev_matches_single_device(tiny_hg):
    """The acceptance row: K=4 graph-partitioned execution on a forced
    8-device host (mesh data=4 so the halo exchange runs the shard_map
    all-gather path) == unpartitioned single-device forward, for
    HAN / RGCN / MAGNN — with nonzero halo_bytes in stage_records."""
    code = textwrap.dedent("""
        import numpy as np, scipy.sparse as sp, jax
        from repro.configs.base import HGNNConfig
        from repro.core.hgraph import HeteroGraph
        from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.serve import build_hgnn_infer

        rng = np.random.default_rng(7)
        counts = {"M": 40, "D": 15, "A": 25}
        dims = {"M": 12, "D": 8, "A": 10}
        feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
                 for t, n in counts.items()}
        def rr(ns, nd, e):
            r = rng.integers(0, ns, e); c = rng.integers(0, nd, e)
            return sp.csr_matrix((np.ones(e, np.float32), (r, c)),
                                 shape=(ns, nd))
        md, ma = rr(40, 15, 60), rr(40, 25, 80)
        hg = HeteroGraph(counts, feats,
                         {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
                          ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
                         name="tiny")
        DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
        DATASET_TARGET["tiny"] = "M"

        mesh = make_smoke_mesh(data=4, model=2)
        cases = [
            dict(model="han", fused=True, partitions=4),
            dict(model="han", fused=True, partitions=4, layers=2),
            dict(model="rgcn", fused=True, partitions=4),
            dict(model="rgcn", fused=True, partitions=4, layers=2),
            dict(model="magnn", partitions=4),
        ]
        for kw in cases:
            cfg = HGNNConfig(dataset="tiny", hidden=16, n_heads=4,
                             n_classes=3, max_degree=12, max_instances=4, **kw)
            built = build_hgnn_infer(cfg, hg, mesh)
            sharded = np.asarray(built.fn(built.params, built.batch))
            ref = build_hgnn_infer(cfg.replace(partitions=0), hg)
            plain = np.asarray(ref.fn(ref.params, ref.batch))
            np.testing.assert_allclose(sharded, plain, rtol=2e-4, atol=2e-4)
            recs = built.executor.stage_records(built.params, built.batch)
            gh = [n for n in recs["stages"] if n.endswith("gather_halo")]
            assert len(gh) == kw.get("layers", 1), kw
            assert all(recs["stages"][n]["halo_bytes"] > 0 for n in gh), kw
            assert recs["partition"]["cut_edges"] > 0, kw
            assert recs["partition"]["layers"] == kw.get("layers", 1), kw
            print("OK", kw)
    """)
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("OK") == 5


# ---------------------------------------------------------------------------
# plan + dispatch invariants
# ---------------------------------------------------------------------------

def test_plan_layout_resolution():
    _tiny_tables()
    assert get_model(_cfg("han", fused=False)).plan().na.layout == "csr"
    assert get_model(_cfg("han", fused=True)).plan().na.layout == "stacked"
    p = get_model(_cfg("han", fused=True, degree_buckets=3)).plan()
    assert p.na.layout == "bucketed"
    assert not p.sa.fuse_epilogue  # epilogue is stacked-only
    p = get_model(_cfg("han", fused=True, fuse_na_sa=True)).plan()
    assert p.sa.fuse_epilogue
    assert get_model(_cfg("rgcn", fused=True)).plan().na.layout == "padded"
    assert get_model(
        _cfg("rgcn", fused=True, degree_buckets=3)).plan().na.layout == "bucketed"
    assert get_model(_cfg("magnn")).plan().na.layout == "instances"
    # CSR layouts refuse to shard
    assert not get_model(_cfg("han", fused=False)).plan().shards_on_mesh
    assert get_model(_cfg("magnn")).plan().shards_on_mesh
    # partitioned plans: PartitionSpec set, epilogue disabled, CSR refused
    p = get_model(_cfg("han", fused=True, partitions=4)).plan()
    assert p.partition is not None and p.partition.k == 4
    p = get_model(_cfg("han", fused=True, fuse_na_sa=True,
                       partitions=4)).plan()
    assert not p.sa.fuse_epilogue  # epilogue needs the single-table stack
    assert get_model(_cfg("rgcn", fused=True)).plan().partition is None
    # multi-layer plans: StagePlan is the L-layer container; layer 0 owns
    # the raw-feature FP, hidden layers the per-model re-projection kind;
    # plan.fp/na/sa keep reading layer 0
    p = get_model(_cfg("han", fused=True, layers=3)).plan()
    assert p.n_layers == 3 and len(p.layers) == 3
    assert p.layers[0].fp.kind == "per_type" and p.layers[0].fp.heads
    assert all(lp.fp.kind == "dense" for lp in p.layers[1:])
    assert all(lp.handoff == "target" for lp in p.layers)
    assert p.na is p.layers[0].na and p.fp is p.layers[0].fp
    p = get_model(_cfg("rgcn", fused=True, layers=2)).plan()
    assert p.layers[1].fp.kind == "identity"
    assert all(lp.handoff == "all" for lp in p.layers)
    p = get_model(_cfg("magnn", layers=2)).plan()
    assert p.layers[1].handoff == "target+carry"
    assert set(p.layers[1].carry) == {"D", "A"}
    assert get_model(_cfg("han", fused=True)).plan().n_layers == 1
    with pytest.raises(ValueError, match="layers must be >= 1"):
        _cfg("han", fused=True, layers=0)


def test_stageplan_rejects_nonuniform_layers():
    """The host-side index tables are built once and reused per layer, so
    NA kind/layout and SA kind must be uniform across the stack."""
    from repro.core.plan import (FPSpec, HeadSpec, LayerPlan, NASpec, SASpec,
                                 StagePlan)

    l0 = LayerPlan(fp=FPSpec(), na=NASpec(kind="gat", layout="stacked"),
                   sa=SASpec(kind="attention"))
    l1 = LayerPlan(fp=FPSpec(), na=NASpec(kind="gat", layout="csr"),
                   sa=SASpec(kind="attention"))
    with pytest.raises(ValueError, match="layer-uniform"):
        StagePlan(model="x", target="M", layers=(l0, l1), head=HeadSpec())
    with pytest.raises(ValueError, match="at least one"):
        StagePlan(model="x", target="M", layers=(), head=HeadSpec())


def test_partitioned_stage_records_report_halo_traffic(tiny_hg):
    """Single-device partitioned run: stage_records grows the gather_halo
    stage with nonzero halo_bytes + the partition cut summary, and the
    stage-additive totals still include it."""
    cfg = _cfg("han", fused=True, partitions=3)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    recs = m.stage_records(params, batch)
    assert set(recs["stages"]) == {"FP", "gather_halo", "NA", "SA", "head"}
    gh = recs["stages"]["gather_halo"]
    assert gh["halo_bytes"] > 0 and gh["hbm_bytes"] > 0
    pt = recs["partition"]
    assert pt["k"] == 3 and 0 < pt["cut_ratio"] <= 1
    assert pt["halo_rows"] > 0 and pt["cut_edges"] == gh["cut_edges"]
    assert recs["total"]["hbm_bytes"] == pytest.approx(
        sum(r["hbm_bytes"] for r in recs["stages"].values()))


def test_mean_aggregate_bucketed_matches_padded(tiny_hg):
    """RGCN satellite: bucketed mean NA == single-K padded mean NA."""
    sub = mp.build_padded(tiny_hg, ["M", "D", "M"], max_degree=16)
    bk = mp.bucket_padded(sub, n_buckets=3)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((sub.n_nodes, 8)), jnp.float32)
    want = stages.mean_aggregate_padded(h, jnp.asarray(sub.nbr),
                                        jnp.asarray(sub.mask))
    buckets = [(jnp.asarray(bk.row_ids[i]), jnp.asarray(bk.nbr[i]),
                jnp.asarray(bk.mask[i])) for i in range(bk.n_buckets)]
    got = stages.mean_aggregate_bucketed(h, buckets, sub.n_nodes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rgcn_bucketed_layout_strictly_smaller(tiny_hg):
    cfg = _cfg("rgcn", fused=True, degree_buckets=3, max_degree=16)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    cfg_p = _cfg("rgcn", fused=True, max_degree=16)
    batch_p = get_model(cfg_p).prepare(tiny_hg)
    for key, buckets in batch["rels"].items():
        assert isinstance(buckets, list)
        padded = sum(b[1].size for b in buckets)
        assert padded <= batch_p["rels"][key][0].size


# ---------------------------------------------------------------------------
# characterization records
# ---------------------------------------------------------------------------

def test_stage_records_sum_to_totals(tiny_hg):
    """Per-stage characterization records must sum to the whole-model
    totals the executor reports (and each stage must be populated)."""
    cfg = _cfg("han", fused=True)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    recs = m.stage_records(params, batch)
    assert set(recs["stages"]) == {"FP", "NA", "SA", "head"}
    for name, r in recs["stages"].items():
        assert r["flops"] > 0, name
        assert r["hbm_bytes"] > 0, name
        assert r["roofline"]["bound"] in ("compute", "memory", "collective")
    assert recs["total"]["flops"] == pytest.approx(
        sum(r["flops"] for r in recs["stages"].values()))
    assert recs["total"]["hbm_bytes"] == pytest.approx(
        sum(r["hbm_bytes"] for r in recs["stages"].values()))


def test_fused_epilogue_saves_an_hbm_pass(tiny_hg):
    """The acceptance invariant, counted via core/characterize.py: with the
    epilogue, the SA stage fn moves at least one full [P, N, D] pass less."""
    from repro.core.characterize import analyze_hlo_text

    def sa_bytes(cfg):
        m = get_model(cfg)
        batch = m.prepare(tiny_hg)
        params = m.init(jax.random.key(0), batch)
        fns = m.executor.stage_fns(params, batch)
        fn, args = fns["SA"]
        rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
        z = args[1]  # the SA input: [P, N, D] stack (or (stack, scores))
        z = z[0] if isinstance(z, tuple) else z
        return rep["total_hbm_bytes"], z.size * z.dtype.itemsize

    two_pass, z_bytes = sa_bytes(_cfg("han", fused=True))
    fused, _ = sa_bytes(_cfg("han", fused=True, fuse_na_sa=True))
    assert two_pass - fused >= 0.9 * z_bytes, (two_pass, fused, z_bytes)


@pytest.mark.parametrize("n,block_n", [(200, 64), (256, 64), (70, 512)])
def test_semantic_scores_streaming_parity(n, block_n):
    """SA pass-1 streaming split: an oversized [P, N, D] stack stays in HBM
    behind double-buffered DMAs (tail chunk aligned to the array end, no
    padded whole-array copy) and must match the resident path / the math —
    including a nonzero bias, which the pad rows must not leak."""
    from repro.kernels.semantic_attn import semantic_scores

    rng = np.random.default_rng(1)
    p, d, hs = 3, 16, 8
    z = jnp.asarray(rng.standard_normal((p, n, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, hs)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(hs) * 0.5, jnp.float32)
    q = jnp.asarray(rng.standard_normal(hs), jnp.float32)
    want = jnp.einsum("pnh,h->pn", jnp.tanh(z @ w + b), q).mean(axis=1)
    # vmem_budget=1 forces the streaming path whenever n > block_n
    got = semantic_scores(z, w, b, q, block_n=block_n, interpret=True,
                          vmem_budget=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    resident = semantic_scores(z, w, b, q, block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(resident),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# request-path sampling: sampled-vs-full parity
# ---------------------------------------------------------------------------

SAMPLED_MATRIX = [
    # with fanout >= max degree and an exact-size rung, a sampled minibatch
    # over ALL targets must reproduce the full-graph forward bit-for-bit
    ("han", {"fused": True}),
    ("han", {"fused": True, "layers": 2}),
    ("han", {"fused": True, "fuse_na_sa": True}),
    ("han", {"fused": True, "degree_buckets": 3}),
    ("han", {"fused": True, "degree_buckets": 3, "layers": 2}),
    ("rgcn", {"fused": True}),
    ("rgcn", {"fused": True, "layers": 2}),
    ("rgcn", {"fused": True, "degree_buckets": 3}),
    ("magnn", {}),
    ("magnn", {"layers": 2}),
]


@pytest.mark.parametrize(
    "model,kw", SAMPLED_MATRIX,
    ids=[f"{m}-{'_'.join(f'{k}{v}' for k, v in kw.items()) or 'base'}"
         for m, kw in SAMPLED_MATRIX])
def test_sampled_minibatch_matches_full_forward(tiny_hg, model, kw):
    """The acceptance row: fan-out >= max degree + an exact-size ladder rung
    means sampling drops nothing, so the sampled minibatch logits over all
    40 targets are BIT-EXACT vs the full-graph forward — per executor
    dispatch arm (stacked, bucketed, fused-epilogue, padded-relational,
    instances) at L in {1, 2}."""
    from repro.serve.sampler import HGNNSampler

    cfg = _cfg(model, fanout=64, sample_ladder=((40, 40),), **kw)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    fn = jax.jit(m.forward)  # the executable serving actually runs
    want = np.asarray(fn(params, batch))
    sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
    sb = sampler.sample(np.arange(40))
    got = np.asarray(fn(params, sb.batch))[sb.target_rows]
    np.testing.assert_array_equal(got, want)


def test_sampled_gcn_matches_full_forward():
    from repro.data.synthetic import make_reddit_like
    from repro.serve.sampler import HGNNSampler

    hg = make_reddit_like(scale=0.005)
    n = hg.node_counts["N"]
    cfg = HGNNConfig(model="gcn", dataset="reddit", hidden=16, n_classes=5,
                     fanout=4096, sample_ladder=((n, n),))
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(0), batch)
    fn = jax.jit(m.forward)
    want = np.asarray(fn(params, batch))
    sampler = HGNNSampler(m.plan(), cfg, hg)
    sb = sampler.sample(np.arange(n))
    got = np.asarray(fn(params, sb.batch))[sb.target_rows]
    np.testing.assert_array_equal(got, want)


def test_sampler_rejects_csr_plans(tiny_hg):
    from repro.serve.sampler import HGNNSampler

    cfg = _cfg("han", fused=False, fanout=4)
    m = get_model(cfg)
    with pytest.raises(ValueError, match="csr"):
        HGNNSampler(m.plan(), cfg, tiny_hg)
    cfg = _cfg("han", fused=True)  # fanout=0: no SampleSpec on the plan
    m = get_model(cfg)
    with pytest.raises(ValueError, match="SampleSpec"):
        HGNNSampler(m.plan(), cfg, tiny_hg)


def test_sample_stage_record_rides_stage_records(tiny_hg):
    """stage_records grows a SAMPLE stage from the sampler's meta: the
    sampled-frontier bytes are the Subgraph-Build traffic of the request
    path, and the compiled-stage totals stay additive without it."""
    from repro.serve.sampler import HGNNSampler

    cfg = _cfg("han", fused=True, fanout=4)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
    sb = sampler.sample(np.arange(10))
    recs = m.executor.stage_records(params, sb.batch, sample_meta=sb.meta)
    assert "SAMPLE" in recs["stages"]
    sm = recs["stages"]["SAMPLE"]
    assert sm["n_targets"] == 10 and sm["fanout"] == 4
    assert sm["frontier_bytes"] > 0 and sm["index_bytes"] > 0
    assert tuple(sm["rung"]) in m.plan().sample.ladder
    # SAMPLE is host-side traffic: the FLOPs/bytes totals still reconcile
    # over the compiled stages only
    assert recs["total"]["flops"] == pytest.approx(
        sum(r["flops"] for n, r in recs["stages"].items() if n != "SAMPLE"))


def test_hgnn_infer_engine_serves_and_characterizes(tiny_hg):
    from repro.launch.serve import build_hgnn_infer
    from repro.serve.engine import HGNNInferEngine

    cfg = _cfg("han", fused=True)
    built = build_hgnn_infer(cfg, tiny_hg)
    engine = HGNNInferEngine(built.executor, built.params, built.batch,
                             fn=built.fn)
    logits = engine.infer()
    assert logits.shape == (40, 3)
    recs = engine.characterize()
    assert {"FP", "NA", "SA"} <= set(recs)
    assert engine.plan.na.layout == "stacked"


# ---------------------------------------------------------------------------
# hot-feature residency (repro.core.residency): cached == uncached, bitwise
# ---------------------------------------------------------------------------

# cached-vs-uncached parity is WITHIN one layout, so the bar is exact
# equality — the cache section holds bitwise row copies and the remapped
# index tables must reproduce the uncached forward to the last ulp
CACHED_MATRIX = [
    ("han", {"fused": False}),
    ("han", {"fused": True}),
    ("han", {"fused": True, "layers": 2}),
    ("han", {"fused": True, "degree_buckets": 3}),
    ("han", {"fused": True, "degree_buckets": 3, "layers": 2}),
    ("han", {"fused": True, "fuse_na_sa": True}),
    ("han", {"fused": True, "fuse_na_sa": True, "layers": 2}),
    ("han", {"fused": True, "partitions": 4}),
    ("han", {"fused": True, "partitions": 4, "layers": 2}),
    ("rgcn", {"fused": False}),
    ("rgcn", {"fused": True}),
    ("rgcn", {"fused": True, "layers": 2}),
    ("rgcn", {"fused": True, "degree_buckets": 3}),
    ("rgcn", {"fused": True, "partitions": 4}),
    ("magnn", {}),
    ("magnn", {"layers": 2}),
    ("magnn", {"partitions": 4}),
]


@pytest.mark.parametrize(
    "model,kw", CACHED_MATRIX,
    ids=[f"{m}-{'_'.join(f'{k}{v}' for k, v in kw.items()) or 'base'}"
         for m, kw in CACHED_MATRIX])
def test_cached_forward_bit_exact(tiny_hg, model, kw):
    m0 = get_model(_cfg(model, **kw))
    b0 = m0.prepare(tiny_hg)
    params = m0.init(jax.random.key(0), b0)
    want = np.asarray(m0.forward(params, b0))

    m1 = get_model(_cfg(model, cache_rows=8, **kw))
    b1 = m1.prepare(tiny_hg)
    assert "residency" in b1
    ctr = b1["residency"]["counters"]
    assert ctr["hits"] + ctr["misses"] == ctr["rows"] > 0
    got = np.asarray(m1.forward(params, b1))
    np.testing.assert_array_equal(got, want)


def test_cached_serving_bit_exact(tiny_hg):
    """Sampled serving with the live cache: the per-step frontier rides the
    engine-level HotRowCache (accounting only — batch shapes never change),
    so cached serving returns bitwise the uncached logits and reports
    residency counters that conserve."""
    from repro.serve.engine import HGNNRequest, HGNNServeEngine
    from repro.serve.sampler import HGNNSampler

    outs = []
    for rows in (0, 8):
        cfg = _cfg("han", fused=True, fanout=64, cache_rows=rows)
        m = get_model(cfg)
        batch = m.prepare(tiny_hg)
        params = m.init(jax.random.key(0), batch)
        sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
        engine = HGNNServeEngine(m.executor, params, sampler, slots=4,
                                 slot_targets=4)
        engine.warmup()
        rng = np.random.default_rng(0)
        reqs = [HGNNRequest(targets=rng.integers(0, 40, size=5))
                for _ in range(6)]
        engine.serve(reqs)
        st = engine.stats()
        assert st["compiles_after_warmup"] == 0
        if rows:
            rd = st["residency"]
            assert rd["hits"] + rd["misses"] == rd["rows"] > 0
            for t, c in rd["per_type"].items():
                assert c["resident"] <= c["capacity"] <= rows
        else:
            assert "residency" not in st
        outs.append(np.concatenate([r.logits for r in reqs]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_cached_stage_records_na_bytes_strictly_decrease(tiny_hg):
    """The headline accounting: with the cache enabled, every NA stage's
    ``hbm_bytes`` strictly decreases (hits x row_bytes saved; the fill is
    charged once, at the first cached stage — inter-layer reuse), and the
    partitioned flow books the savings on the ``gather_halo`` records."""
    for model, kw, stage_suffix in [
            ("han", {"fused": True, "layers": 2}, "NA"),
            ("rgcn", {"fused": False, "layers": 2}, "NA"),
            ("han", {"fused": True, "layers": 2, "partitions": 4},
             "gather_halo")]:
        m0 = get_model(_cfg(model, **kw))
        b0 = m0.prepare(tiny_hg)
        params = m0.init(jax.random.key(0), b0)
        r0 = m0.stage_records(params, b0)
        m1 = get_model(_cfg(model, cache_rows=12, **kw))
        b1 = m1.prepare(tiny_hg)
        r1 = m1.stage_records(params, b1)
        rr = r1["residency"]
        assert rr["hits"] > 0
        assert rr["hit_rate"] == pytest.approx(rr["hits"] / rr["rows"])
        names = [n for n in r1["stages"] if n.endswith(stage_suffix)]
        assert len(names) == 2  # one per layer
        for i, n in enumerate(names):
            assert (r1["stages"][n]["hbm_bytes"]
                    < r0["stages"][n]["hbm_bytes"]), (model, n)
            saved = r1["stages"][n]["residency_bytes_saved"]
            want = rr["bytes_saved_per_layer"] - (
                rr["fill_bytes"] if i == 0 else 0)
            assert saved == want
        # uncached stages are untouched by the accounting
        for n in r1["stages"]:
            if not n.endswith(stage_suffix):
                assert (r1["stages"][n]["hbm_bytes"]
                        == r0["stages"][n]["hbm_bytes"]), (model, n)
        assert r1["total"]["hbm_bytes"] < r0["total"]["hbm_bytes"]
