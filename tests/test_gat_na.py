"""Fused GAT-NA kernel subsystem: interpret-mode parity vs the refs across
heads / degree skew / empty-neighbor rows, the HBM-streaming path (source
table larger than one feature block), the one-launch stacked form, and the
degree-bucketed layout + dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metapath as mp, stages
from repro.kernels import ref
from repro.kernels.fused_fp_na import fused_fp_na
from repro.kernels.gat_na import gat_na
from repro.kernels.segment_spmm import segment_spmm
from repro.kernels.streaming import chunk_schedule

RNG = np.random.default_rng(0)


def _arr(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, jnp.float32)


def _gat_case(n, m, k, h, dh, skew=False):
    """Random padded-GAT inputs; ``skew=True`` gives power-law-ish degrees
    (many low-degree rows, a few full rows) plus empty-neighbor rows."""
    h_dst = _arr((n, h, dh))
    h_src = _arr((m, h, dh))
    nbr = jnp.asarray(RNG.integers(0, m, (n, k)), jnp.int32)
    if skew:
        deg = np.minimum(RNG.zipf(1.5, n), k)
        deg[:3] = 0  # empty-neighbor rows
        mask = (np.arange(k)[None, :] < deg[:, None]).astype(np.float32)
    else:
        mask = (RNG.random((n, k)) < 0.8).astype(np.float32)
        mask[1] = 0.0  # one empty-neighbor row
    mask = jnp.asarray(mask)
    p = {"a_dst": _arr((h, dh), 0.2), "a_src": _arr((h, dh), 0.2)}
    return p, h_dst, h_src, nbr, mask


@pytest.mark.parametrize("h,dh", [(1, 8), (4, 16), (8, 8)])
def test_gat_na_resident_parity(h, dh):
    p, h_dst, h_src, nbr, mask = _gat_case(50, 45, 7, h, dh)
    want = stages.gat_aggregate_padded(p, h_dst, h_src, nbr, mask)
    got = gat_na(p, h_dst, h_src, nbr, mask, block_n=32, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gat_na_degree_skew_and_empty_rows():
    p, h_dst, h_src, nbr, mask = _gat_case(60, 40, 9, 4, 8, skew=True)
    want = stages.gat_aggregate_padded(p, h_dst, h_src, nbr, mask)
    got = gat_na(p, h_dst, h_src, nbr, mask, block_n=16, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(got[:3])).max() == 0.0  # empty rows -> zeros


@pytest.mark.parametrize("block_m", [8, 16])
def test_gat_na_streaming_parity(block_m):
    """Source table spans several HBM chunks -> the double-buffered DMA path
    (not the resident BlockSpec path) must still match the oracle."""
    p, h_dst, h_src, nbr, mask = _gat_case(40, 45, 6, 4, 8, skew=True)
    n_chunks = -(-45 // block_m)
    _, count = chunk_schedule(jnp.pad(nbr, ((0, 24), (0, 0))),
                              jnp.pad(mask, ((0, 24), (0, 0))),
                              16, n_chunks, block_m)
    assert int(count.max()) > 1  # streaming genuinely multi-chunk
    want = stages.gat_aggregate_padded(p, h_dst, h_src, nbr, mask)
    got = gat_na(p, h_dst, h_src, nbr, mask, block_n=16, block_m=block_m,
                 interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gat_na_stacked_single_launch(monkeypatch):
    """The whole [P, N, K] metapath stack must be ONE pallas_call."""
    import repro.kernels.gat_na as gmod

    P, n, m, k, h, dh = 3, 40, 30, 5, 4, 8
    h_dst, h_src = _arr((n, h, dh)), _arr((m, h, dh))
    nbr = jnp.asarray(RNG.integers(0, m, (P, n, k)), jnp.int32)
    mask = jnp.asarray(RNG.random((P, n, k)) < 0.7, jnp.float32)
    ps = {kk: jnp.stack([_arr((h, dh), 0.2) for _ in range(P)])
          for kk in ("a_dst", "a_src")}
    calls = []
    orig = gmod.pl.pallas_call

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(gmod.pl, "pallas_call", counting)
    got = gat_na(ps, h_dst, h_src, nbr, mask, block_n=16, interpret=True)
    assert len(calls) == 1
    want = ref.gat_na(ps, h_dst, h_src, nbr, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # streaming stacked form too
    got_s = gat_na(ps, h_dst, h_src, nbr, mask, block_n=16, block_m=8,
                   interpret=True)
    assert len(calls) == 2
    np.testing.assert_allclose(got_s, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mean", [True, False])
def test_segment_spmm_streaming_parity(mean):
    """Table larger than one block: streaming path, incl. float edge weights
    (the folded-alpha calling convention)."""
    h = _arr((300, 33))
    nbr = jnp.asarray(RNG.integers(0, 300, (57, 9)), jnp.int32)
    w = jnp.asarray(RNG.random((57, 9)) * (RNG.random((57, 9)) < 0.7),
                    jnp.float32)
    want = ref.segment_spmm(h, nbr, w, mean=mean)
    got = segment_spmm(h, nbr, w, mean=mean, block_n=16, block_m=64,
                       interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fused_fp_na_streaming_parity():
    x, w = _arr((80, 70)), _arr((70, 32))
    nbr = jnp.asarray(RNG.integers(0, 80, (33, 4)), jnp.int32)
    mask = jnp.asarray(RNG.random((33, 4)) < 0.8, jnp.float32)
    want = ref.fused_fp_na(x, w, nbr, mask)
    got = fused_fp_na(x, w, nbr, mask, block_n=16, block_f=32, block_m=16,
                      interpret=True)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_chunk_schedule_skips_untouched_chunks():
    """Tiles only schedule the chunks their neighbors actually touch."""
    nbr = jnp.asarray([[0, 1, 50], [2, 51, 52]] * 4, jnp.int32)  # chunks 0, 3
    mask = jnp.ones((8, 3), jnp.float32)
    sched, count = chunk_schedule(nbr, mask, block_n=8, n_chunks=4, block_m=16)
    assert count.tolist() == [2]
    assert sched[0, :2].tolist() == [0, 3]
    # masked-out edges don't pull chunks in (drop both chunk-3 columns)
    mask2 = mask.at[:, 1:].set(0.0)
    _, count2 = chunk_schedule(nbr, mask2, block_n=8, n_chunks=4, block_m=16)
    assert count2.tolist() == [1]


def test_bucket_padded_invariants(tiny_hg):
    sub = mp.build_padded(tiny_hg, ["M", "D", "M"], max_degree=16)
    bk = mp.bucket_padded(sub, n_buckets=3)
    # rows partition the node set
    all_rows = np.sort(np.concatenate(bk.row_ids))
    np.testing.assert_array_equal(all_rows, np.arange(sub.n_nodes))
    # no edge dropped, caps ascending, layout strictly smaller
    assert sum(m.sum() for m in bk.mask) == sub.mask.sum()
    caps = [nb.shape[1] for nb in bk.nbr]
    assert caps == sorted(caps) and caps[-1] <= sub.max_degree
    assert bk.padded_edges <= sub.nbr.size
    # every row fits its bucket cap
    for rows, m in zip(bk.row_ids, bk.mask):
        assert (m.sum(1) <= m.shape[1]).all()
        np.testing.assert_array_equal(m.sum(1), sub.mask[rows].sum(1))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_bucketed_dispatch_matches_padded(tiny_hg, use_kernel):
    sub = mp.build_padded(tiny_hg, ["M", "D", "M"], max_degree=16)
    bk = mp.bucket_padded(sub, n_buckets=3)
    n, h, dh = sub.n_nodes, 4, 8
    hfeat = _arr((n, h, dh))
    p = stages.init_gat(jax.random.key(1), h, dh)
    want = stages.gat_aggregate_padded(p, hfeat, hfeat,
                                       jnp.asarray(sub.nbr),
                                       jnp.asarray(sub.mask))
    buckets = [(jnp.asarray(bk.row_ids[i]), jnp.asarray(bk.nbr[i]),
                jnp.asarray(bk.mask[i])) for i in range(bk.n_buckets)]
    agg_fn = None
    if use_kernel:
        agg_fn = lambda pp, hd, hs, nn, mm: gat_na(
            pp, hd, hs, nn, mm, block_n=16, interpret=True)
    got = stages.gat_aggregate_bucketed(p, hfeat, hfeat, buckets,
                                        agg_fn=agg_fn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
