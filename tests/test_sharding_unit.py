"""Unit tests for repro.dist — single-device, no subprocess harness.

Covers the resolve-or-replicate contract edge cases (empty specs, nested
axis tuples, 1-sized mesh axes, divisibility fallback) and ``param_specs``
over every registered model family, plus the stage-aware sharded HGNN
inference entry point off-mesh.
"""
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_leaves_with_path, tree_structure

from repro.configs import get_reduced, list_archs
from repro.dist.param_sharding import param_specs
from repro.dist.sharding import (
    BATCH,
    MODEL,
    current_mesh,
    resolve_spec,
    shard,
    use_mesh,
)


class FakeMesh(NamedTuple):
    """Just enough mesh surface for resolve_spec (axis_names + shape)."""

    axis_names: tuple
    shape: dict


MESH_2x4 = FakeMesh(("data", "model"), {"data": 2, "model": 4})


def _unit_mesh() -> Mesh:
    """Real 1-device mesh with 1-sized data/model axes."""
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# resolve_spec
# ---------------------------------------------------------------------------


def test_empty_spec_replicates():
    assert resolve_spec((8, 16), (), MESH_2x4) == P()


def test_spec_shorter_than_shape():
    assert resolve_spec((8, 16, 32), ("data",), MESH_2x4) == P("data")


def test_spec_longer_than_shape_truncates():
    assert resolve_spec((8,), ("data", "model"), MESH_2x4) == P("data")


def test_divisibility_guard_replicates():
    assert resolve_spec((8, 15), (None, "model"), MESH_2x4) == P(None, None)
    assert resolve_spec((7, 16), ("data", "model"), MESH_2x4) == P(None, "model")


def test_unknown_axis_dropped():
    assert resolve_spec((8, 16), (("pod", "data"), None), MESH_2x4) == P("data", None)
    assert resolve_spec((8,), ("pod",), MESH_2x4) == P(None)


def test_nested_axis_tuples_flatten():
    spec = resolve_spec((16,), ((("pod", "data"), "model"),), MESH_2x4)
    assert spec == P(("data", "model"))


def test_tuple_divisibility_uses_product():
    # 8 % (2*4) == 0 -> sharded over both; 12 % 8 != 0 -> replicated
    assert resolve_spec((8,), (("data", "model"),), MESH_2x4) == P(("data", "model"))
    assert resolve_spec((12,), (("data", "model"),), MESH_2x4) == P(None)


def test_one_sized_mesh_axes_retained():
    unit = FakeMesh(("data", "model"), {"data": 1, "model": 1})
    # size-1 axes divide everything; the (legal) axis name is kept
    assert resolve_spec((7, 13), ("data", "model"), unit) == P("data", "model")
    assert resolve_spec((7,), (BATCH,), unit) == P("data")


def test_single_axis_tuple_collapses_to_name():
    # result must compare equal to a hand-written P('data', ...)
    spec = resolve_spec((8, 16), (BATCH, MODEL), MESH_2x4)
    assert spec == P("data", "model")


# ---------------------------------------------------------------------------
# shard / use_mesh
# ---------------------------------------------------------------------------


def test_shard_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert current_mesh() is None
    assert shard(x, BATCH, MODEL) is x


def test_use_mesh_nests_and_restores():
    m = _unit_mesh()
    with use_mesh(m) as m1:
        assert current_mesh() is m1
        with use_mesh(m):
            assert current_mesh() is m
        assert current_mesh() is m1
    assert current_mesh() is None


def test_shard_applies_constraint_under_mesh():
    m = _unit_mesh()
    with use_mesh(m):
        y = shard(jnp.ones((4, 8)), BATCH, MODEL)
    assert y.shape == (4, 8)
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 8)))


# ---------------------------------------------------------------------------
# param_specs on every registered model family
# ---------------------------------------------------------------------------


def _abstract_params(cfg):
    if cfg.family == "encdec":
        from repro.nn.encdec import init_encdec_params

        return jax.eval_shape(lambda: init_encdec_params(jax.random.key(0), cfg))
    from repro.nn.transformer import init_lm_params

    return jax.eval_shape(lambda: init_lm_params(jax.random.key(0), cfg))


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_every_family(arch):
    cfg = get_reduced(arch)
    params = _abstract_params(cfg)
    mesh = _unit_mesh()
    sh = param_specs(params, mesh, fsdp=cfg.fsdp, fsdp_experts=cfg.fsdp_experts)
    assert tree_structure(sh) == tree_structure(params)

    flat_p = dict(tree_leaves_with_path(params))
    for path, ns in tree_leaves_with_path(sh):
        assert isinstance(ns, NamedSharding)
        leaf = flat_p[path]
        assert len(ns.spec) in (0, leaf.ndim)
        names = [k.key for k in path if isinstance(k, DictKey)]
        name, parent = names[-1], (names[-2] if len(names) >= 2 else "")
        spec = tuple(ns.spec) + (None,) * (leaf.ndim - len(ns.spec))
        if parent == "moe" and name in ("w_gate", "w_up", "w_down"):
            assert spec[-3] == "model", (path, spec)  # expert parallelism
        elif name in ("wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_x",
                      "w_dt", "lm_head"):
            assert spec[-1] == "model", (path, spec)  # column-sharded
        elif name in ("wo", "w_down", "out_proj"):
            assert spec[-2] == "model", (path, spec)  # row-sharded
        elif name == "embed":
            assert spec[0] == "model", (path, spec)  # vocab-sharded logits
        elif leaf.ndim <= 1:
            # small EW-Type vectors (norm scales, biases, A_log/D) replicate
            assert all(s is None for s in spec), (path, spec)


def test_param_specs_no_fsdp_drops_data_axis():
    cfg = get_reduced("granite-8b")
    params = _abstract_params(cfg)
    sh = param_specs(params, _unit_mesh(), fsdp=False, fsdp_experts=False)
    for _, ns in tree_leaves_with_path(sh):
        assert "data" not in jax.tree_util.tree_leaves(tuple(ns.spec))


def test_param_specs_guard_on_indivisible_dims():
    # 15-wide output dim on a model=4 mesh must fall back to replication
    from repro.dist.param_sharding import _leaf_spec

    leaf = jax.ShapeDtypeStruct((8, 15), jnp.float32)
    path = (DictKey("attn"), DictKey("wq"))
    spec = _leaf_spec(path, leaf, fsdp=False, fsdp_experts=False)
    assert spec == (None, "model")
    assert resolve_spec(leaf.shape, spec, MESH_2x4) == P(None, None)


# ---------------------------------------------------------------------------
# stage-aware sharded stage variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("on_mesh", [False, True])
def test_gat_aggregate_padded_sharded_matches_unsharded(on_mesh):
    from repro.core import stages

    rng = np.random.default_rng(3)
    n, m, h, dh, k = 10, 12, 2, 4, 5
    p = stages.init_gat(jax.random.key(0), h, dh)
    h_dst = jnp.asarray(rng.standard_normal((n, h, dh)), jnp.float32)
    h_src = jnp.asarray(rng.standard_normal((m, h, dh)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, m, (n, k)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (n, k)), jnp.float32)

    ref = stages.gat_aggregate_padded(p, h_dst, h_src, nbr, mask)
    if on_mesh:
        with use_mesh(_unit_mesh()):
            out = jax.jit(stages.gat_aggregate_padded_sharded)(
                p, h_dst, h_src, nbr, mask)
    else:
        out = stages.gat_aggregate_padded_sharded(p, h_dst, h_src, nbr, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# stage-aware sharded HGNN inference entry (off-mesh path)
# ---------------------------------------------------------------------------


def test_hgnn_infer_entry_matches_plain_forward(tiny_hg):
    from repro.configs.base import HGNNConfig
    from repro.core.models import get_model
    from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
    from repro.launch.serve import build_hgnn_infer

    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"
    cfg = HGNNConfig(model="han", dataset="tiny", hidden=16, n_heads=4,
                     n_classes=3, max_degree=12, fused=True)
    built = build_hgnn_infer(cfg, tiny_hg)
    logits = built.fn(built.params, built.batch)
    assert logits.shape == (40, 3)
    assert bool(jnp.isfinite(logits).all())

    model = get_model(cfg)
    batch = model.prepare(tiny_hg)
    params = model.init(jax.random.key(cfg.seed), batch)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(model.forward(params, batch)),
                               rtol=2e-5, atol=2e-5)


def test_hgnn_infer_rejects_unfused_on_mesh(tiny_hg):
    from repro.configs.base import HGNNConfig
    from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
    from repro.launch.serve import build_hgnn_infer

    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"
    cfg = HGNNConfig(model="han", dataset="tiny", fused=False)
    with pytest.raises(ValueError, match="fused"):
        build_hgnn_infer(cfg, tiny_hg, mesh=_unit_mesh())
