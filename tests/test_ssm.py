"""Mamba2 SSD: chunked == sequential oracle (hypothesis-swept), block decode
consistency, state propagation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, SSMConfig
from repro.nn.ssm import (
    init_mamba2,
    init_mamba_cache,
    mamba2_block,
    ssd_chunked,
    ssd_sequential,
)

RNG = np.random.default_rng(2)


def _ssd_inputs(b, s, nh, hd, n):
    x = jnp.asarray(RNG.standard_normal((b, s, nh, hd)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.random((b, s, nh)) * 0.5 + 0.01, jnp.float32)
    a = -jnp.asarray(RNG.random(nh) * 2 + 0.1, jnp.float32)
    bp = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, jnp.float32)
    cp = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, jnp.float32)
    return x, dt, a, bp, cp


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]), s=st.sampled_from([32, 64]),
       nh=st.integers(1, 4))
def test_ssd_chunked_matches_sequential(chunk, s, nh):
    x, dt, a, bp, cp = _ssd_inputs(2, s, nh, 8, 12)
    y1, s1 = ssd_chunked(x, dt, a, bp, cp, chunk=chunk)
    y2, s2 = ssd_sequential(x, dt, a, bp, cp)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_ssd_state_carries_across_calls():
    """Running two halves with carried state == one full pass."""
    x, dt, a, bp, cp = _ssd_inputs(1, 64, 2, 8, 8)
    y_full, s_full = ssd_chunked(x, dt, a, bp, cp, chunk=16)
    y1, s1 = ssd_chunked(x[:, :32], dt[:, :32], a, bp[:, :32], cp[:, :32], 16)
    y2, s2 = ssd_chunked(x[:, 32:], dt[:, 32:], a, bp[:, 32:], cp[:, 32:], 16,
                         init_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s2, s_full, rtol=2e-4, atol=2e-4)


def test_ssd_decay_kills_history():
    """With huge decay (dt*|a| >> 1), output depends only on current input."""
    b, s, nh, hd, n = 1, 16, 1, 4, 4
    x, dt, _, bp, cp = _ssd_inputs(b, s, nh, hd, n)
    a = jnp.asarray([-100.0])
    y, _ = ssd_sequential(x, jnp.ones_like(dt), a, bp, cp)
    # expected: y_t = C_t . (dt x_t (x) B_t)   (history fully decayed)
    want = jnp.einsum("bn,bhd,bn->bh d".replace(" ", ""),
                      cp[:, 3], x[:, 3, :, :] * 1.0, bp[:, 3])
    np.testing.assert_allclose(y[:, 3], want, rtol=1e-3, atol=1e-3)


def _tiny_cfg():
    return ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=24, n_heads=3,
        n_kv_heads=3, d_ff=0, vocab=50,
        ssm=SSMConfig(d_state=8, head_dim=8, expand=2, d_conv=4, chunk=8),
        dtype="float32", param_dtype="float32")


def test_mamba_block_prefill_equals_stepped_decode():
    cfg = _tiny_cfg()
    params = init_mamba2(jax.random.key(0), cfg.d_model, cfg.ssm, 1, "float32")
    x = jnp.asarray(RNG.standard_normal((2, 24, cfg.d_model)) * 0.3, jnp.float32)
    full, final_cache = mamba2_block(params, cfg, x, return_state=True)
    cache = init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        o, cache = mamba2_block(params, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, rtol=2e-3, atol=2e-3)
    # the prefill-returned state matches the stepped state
    np.testing.assert_allclose(cache.state, final_cache.state, rtol=2e-3, atol=2e-3)
