"""End-to-end behaviour tests for the paper's system: HGNN training improves
loss on a synthetic dataset; the fused (guideline-optimized) path tracks the
baseline; the serving engine generates; the characterizer reproduces the
paper's FP-is-DM-dominated / NA-is-TB-dominated structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import make_dataset


@pytest.fixture(scope="module")
def imdb():
    return make_dataset("imdb")


def test_han_end_to_end_training(imdb):
    """Train HAN on synthetic IMDB for a few steps: loss decreases."""
    cfg = HGNNConfig(model="han", dataset="imdb", hidden=32, n_heads=4,
                     n_classes=4, max_degree=16)
    m = get_model(cfg)
    batch = m.prepare(imdb)
    params = m.init(jax.random.key(0), batch)
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, 4, batch["n_nodes"]), jnp.int32)

    def loss_fn(p):
        logits = m.forward(p, batch)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
        return (lse - gold).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    first = None
    for _ in range(12):
        loss, g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))


def test_rgcn_inference_all_datasets():
    for ds in ("imdb", "acm"):
        hg = make_dataset(ds)
        cfg = HGNNConfig(model="rgcn", dataset=ds, hidden=16, n_classes=3,
                         max_degree=8)
        m = get_model(cfg)
        batch = m.prepare(hg)
        params = m.init(jax.random.key(1), batch)
        logits = m.forward(params, batch)
        assert bool(jnp.isfinite(logits).all()), ds


def test_characterizer_reproduces_paper_stage_structure(imdb):
    """Paper §4.2/§4.3: FP is DM-dominated; NA (CSR/segment path) is
    TB-heavy. Verified on our own compiled stages."""
    from repro.core.characterize import analyze_hlo_text

    cfg = HGNNConfig(model="han", dataset="imdb", hidden=64, n_heads=8,
                     n_classes=4)
    m = get_model(cfg)
    batch = m.prepare(imdb)
    params = m.init(jax.random.key(0), batch)

    fp = jax.jit(lambda p, f: m.fp(p, {**batch, "feats": f}))
    comp = fp.lower(params, batch["feats"]).compile()
    rep = analyze_hlo_text(comp.as_text())
    dm = rep["flops_by_class"].get("DM", 0)
    assert dm > 0.9 * rep["total_flops"], rep["flops_by_class"]

    h = m.fp(params, batch)
    na = jax.jit(lambda p, hh: m.na(p, batch, hh))
    comp = na.lower(params, h).compile()
    rep = analyze_hlo_text(comp.as_text())
    tb_bytes = rep["hbm_bytes_by_class"].get("TB", 0)
    assert tb_bytes > 0.3 * rep["total_hbm_bytes"], rep["hbm_bytes_by_class"]


def test_serve_engine_generates(tiny_cfg_base):
    from repro.configs.base import ModelConfig
    from repro.nn.transformer import init_lm_params
    from repro.serve.engine import Request, ServeEngine

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    params = init_lm_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_tokens=6) for _ in range(3)]
    done = engine.generate(reqs)
    for r in done:
        assert r.out_tokens is not None and 1 <= len(r.out_tokens) <= 6
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_greedy_generation_deterministic(tiny_cfg_base):
    from repro.configs.base import ModelConfig
    from repro.nn.transformer import init_lm_params
    from repro.serve.engine import Request, ServeEngine

    cfg = ModelConfig(name="d", family="dense", **tiny_cfg_base)
    params = init_lm_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    outs = []
    for _ in range(2):
        engine = ServeEngine(cfg, params, batch_slots=1, max_len=32)
        r = engine.generate([Request(prompt=prompt, max_tokens=5)])[0]
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]
