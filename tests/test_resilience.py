"""Serving resilience layer (repro.serve.resilience + repro.serve.faults).

The ISSUE's acceptance behaviors, pinned deterministically:

  * admission control turns bad traffic into structured REJECTED statuses
    (dtype, id range, size cap) and sheds on a bounded queue — never a
    mid-batch crash;
  * degenerate (zero-target) requests complete OK at admission with
    ``(0, n_classes)`` logits and never occupy a refill iteration;
  * duplicate target ids are served once and fanned back out bit-exact;
  * deadlines complete requests PARTIAL with exactly the rows served so far;
  * transient injected faults are absorbed by bounded retries (requests
    still OK), persistent faults fail only the affected slots' requests;
  * SLO-driven degradation moves strictly inside the warmed ladder
    (``compiles_after_warmup`` stays 0) and recovers when pressure drops;
  * partition loss fails over to a survivors-only spec and post-failover
    outputs are bit-exact vs a never-failed run.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
from repro.serve.engine import HGNNRequest, HGNNServeEngine
from repro.serve.faults import Fault, FaultInjector, InjectedFault
from repro.serve.resilience import (
    FAILED, OK, PARTIAL, REJECTED, AdmissionController, DegradationController,
    ResilienceConfig, RetryPolicy, StepFailure, finalize_request)
from repro.serve.sampler import HGNNSampler


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"


def _build(tiny_hg, model="han", fanout=64, **kw):
    _tiny_tables()
    kw = {"max_degree": 48, "max_instances": 4, "fused": True, **kw}
    cfg = HGNNConfig(model=model, dataset="tiny", hidden=16, n_heads=4,
                     n_classes=3, fanout=fanout, **kw)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    fn = jax.jit(m.executor.forward)
    full = np.asarray(fn(params, batch))
    sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
    return m, params, fn, full, sampler


def _engine(tiny_hg, res=None, injector=None, slots=4, slot_targets=2,
            warm=True, **kw):
    m, params, fn, full, sampler = _build(tiny_hg, **kw)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=slots,
                          slot_targets=slot_targets, fn=fn,
                          resilience_cfg=res, injector=injector)
    if warm:
        eng.warmup()
    return eng, full


def _mixed_requests(n, n_nodes=40, seed=3):
    rng = np.random.default_rng(seed)
    return [HGNNRequest(targets=rng.integers(
        0, n_nodes, size=int(rng.integers(1, 9)))) for _ in range(n)]


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


def test_fault_injector_seeded_is_deterministic():
    a = FaultInjector.seeded(5, n_steps=20, sampler=3, forward=2,
                             persistent_sampler=1, latency_steps=4)
    b = FaultInjector.seeded(5, n_steps=20, sampler=3, forward=2,
                             persistent_sampler=1, latency_steps=4)
    assert a.faults == b.faults
    kinds = [f.kind for f in a.faults]
    assert kinds.count("sampler") == 4 and kinds.count("forward") == 2
    assert kinds.count("latency") == 4
    # exception faults land on distinct steps
    exc_steps = [f.step for f in a.faults if f.kind in ("sampler", "forward")]
    assert len(exc_steps) == len(set(exc_steps))
    c = FaultInjector.seeded(6, n_steps=20, sampler=3, forward=2,
                             persistent_sampler=1, latency_steps=4)
    assert c.faults != a.faults


def test_fault_injector_hooks():
    inj = FaultInjector([Fault(step=2, kind="sampler", attempts=2),
                         Fault(step=3, kind="latency", latency_s=0.5),
                         Fault(step=4, kind="partition", partition=1)])
    inj.check("sampler", 1, 0)  # no fault scheduled -> no raise
    with pytest.raises(InjectedFault):
        inj.check("sampler", 2, 0)
    with pytest.raises(InjectedFault):
        inj.check("sampler", 2, 1)
    inj.check("sampler", 2, 2)  # attempts window exhausted
    assert inj.latency_s(1) == 0.0
    assert inj.latency_s(3) == 0.5
    assert inj.partition_loss(1) is None
    assert inj.partition_loss(4) == 1
    assert inj.counters == {"injected_sampler": 2, "injected_forward": 0,
                            "injected_latency_steps": 1,
                            "injected_partition_losses": 1}
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(step=0, kind="gpu_on_fire")


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_rejects_malformed_requests(tiny_hg):
    eng, full = _engine(tiny_hg, res=ResilienceConfig(max_request_targets=6))
    reqs = [HGNNRequest(targets=np.array([1.5, 2.5])),          # bad dtype
            HGNNRequest(targets=np.array([0, 40])),             # out of range
            HGNNRequest(targets=np.array([-1, 3])),             # negative
            HGNNRequest(targets=np.arange(7)),                  # over size cap
            HGNNRequest(targets=np.array([5, 7]))]              # fine
    eng.serve(reqs)
    assert [r.status for r in reqs[:4]] == [REJECTED] * 4
    for r in reqs[:4]:
        assert r.error and r.logits.shape == (0, 3) and r.served.size == 0
    assert reqs[4].status == OK
    np.testing.assert_array_equal(reqs[4].logits, full[[5, 7]])
    rs = eng.stats()["resilience"]
    assert rs["rejected"] == 4 and rs["admitted"] == 1 and rs["shed"] == 0


def test_bounded_queue_sheds_overflow(tiny_hg):
    eng, full = _engine(tiny_hg, res=ResilienceConfig(max_queue=3))
    reqs = _mixed_requests(8)
    eng.serve(reqs)
    statuses = [r.status for r in reqs]
    assert statuses[:3] == [OK] * 3
    assert statuses[3:] == [REJECTED] * 5
    rs = eng.stats()["resilience"]
    assert rs["shed"] == 5 and rs["rejected"] == 5 and rs["admitted"] == 3
    for r in reqs[:3]:
        np.testing.assert_array_equal(r.logits, full[r.targets])


def test_dedup_serves_unique_ids_and_fans_back_out(tiny_hg):
    eng, full = _engine(tiny_hg, slots=2, slot_targets=2)
    r = HGNNRequest(targets=np.array([7, 3, 7, 7, 3, 9]))
    eng.serve([r])
    assert r.status == OK
    np.testing.assert_array_equal(r.logits, full[r.targets])
    np.testing.assert_array_equal(r.served, r.targets)
    rs = eng.stats()["resilience"]
    assert rs["deduped_rows"] == 3  # 6 rows, 3 unique ids
    # only the 3 unique ids hit the union batch: ceil(3/2) forward steps
    assert eng.stats()["steps"] == 2


def test_degenerate_requests_never_occupy_a_refill_iteration(tiny_hg):
    """Regression (satellite): zero-target requests used to enter the queue
    and burn a refill slot each.  They must complete OK at admission with
    ``(0, n_classes)`` logits, leaving the step count identical to a queue
    without them."""
    eng, full = _engine(tiny_hg, slots=2, slot_targets=2)
    degens = [HGNNRequest(targets=np.zeros(0, np.int64)) for _ in range(6)]
    real = HGNNRequest(targets=np.array([4, 11, 23]))
    eng.serve(degens[:3] + [real] + degens[3:])
    steps_mixed = eng.stats()["steps"]
    for d in degens:
        assert d.status == OK
        assert d.logits.shape == (0, 3)
        assert d.served.size == 0
    np.testing.assert_array_equal(real.logits, full[real.targets])
    assert eng.stats()["resilience"]["degenerate_completed"] == 6

    eng2, _ = _engine(tiny_hg, slots=2, slot_targets=2)
    eng2.serve([HGNNRequest(targets=np.array([4, 11, 23]))])
    assert steps_mixed == eng2.stats()["steps"]


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_expired_deadline_completes_partial_with_zero_rows(tiny_hg):
    eng, full = _engine(tiny_hg, res=ResilienceConfig(deadline_ms=0.0))
    reqs = _mixed_requests(5)
    eng.serve(reqs)
    for r in reqs:
        assert r.status == PARTIAL
        assert r.error == "deadline expired"
        assert r.logits.shape == (0, 3) and r.served.size == 0
    rs = eng.stats()["resilience"]
    assert rs["deadline_expired"] == 5 and rs["partial_requests"] == 5


def test_per_request_deadline_overrides_engine_default(tiny_hg):
    eng, full = _engine(tiny_hg, res=ResilienceConfig(deadline_ms=0.0))
    fast = HGNNRequest(targets=np.array([2, 8]), deadline_ms=60_000.0)
    doomed = HGNNRequest(targets=np.array([1, 3]))
    eng.serve([doomed, fast])
    assert doomed.status == PARTIAL
    assert fast.status == OK
    np.testing.assert_array_equal(fast.logits, full[[2, 8]])


def test_partial_finalize_serves_exact_prefix(tiny_hg):
    """finalize_request's compaction: with ``_done`` rows of the deduped
    view served, PARTIAL keeps exactly the target rows whose unique id was
    served, in request order, with ``served`` naming them."""
    eng, full = _engine(tiny_hg, warm=False)
    r = HGNNRequest(targets=np.array([9, 2, 9, 5, 2]))
    assert eng.admission.admit(r, 0, now=0.0)
    # unique ids sorted: [2, 5, 9]; serve the first 2 (ids 2 and 5)
    r._buf = np.arange(9, dtype=np.float32).reshape(3, 3)
    r._done = 2
    finalize_request(r, PARTIAL, 3, error="deadline expired")
    np.testing.assert_array_equal(r.served, [2, 5, 2])
    np.testing.assert_array_equal(r.logits, r._buf[[0, 1, 0]])
    assert r.status == PARTIAL


# ---------------------------------------------------------------------------
# retries and step failure
# ---------------------------------------------------------------------------


def test_retry_policy_bounds_and_counters():
    res = ResilienceConfig(max_retries=2)
    pol = RetryPolicy(res)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert pol.run("sampler", flaky) == "ok"
    assert len(calls) == 3
    assert pol.counters["sampler_retries"] == 2

    with pytest.raises(StepFailure, match="forward failed after retries"):
        pol.run("forward", lambda: (_ for _ in ()).throw(RuntimeError("die")))
    assert pol.counters["forward_retries"] == 2
    assert pol.counters["failed_steps"] == 1


def test_transient_faults_are_absorbed_by_retries(tiny_hg):
    inj = FaultInjector([Fault(step=1, kind="sampler", attempts=1),
                         Fault(step=2, kind="forward", attempts=2)])
    eng, full = _engine(tiny_hg, injector=inj)
    reqs = _mixed_requests(10)
    eng.serve(reqs)
    for r in reqs:
        assert r.status == OK
        np.testing.assert_array_equal(r.logits, full[r.targets])
    rs = eng.stats()["resilience"]
    assert rs["sampler_retries"] == 1 and rs["forward_retries"] == 2
    assert rs["retries"] == 3 and rs["failed_steps"] == 0
    assert rs["injected"] == {"injected_sampler": 1, "injected_forward": 2,
                              "injected_latency_steps": 0,
                              "injected_partition_losses": 0}
    assert eng.stats()["compiles_after_warmup"] == 0


def test_persistent_fault_fails_only_the_affected_slots(tiny_hg):
    """A persistent sampler fault at step 0 fails exactly the requests in
    that step's slots; the freed slots refill and the rest of the queue
    completes OK — no uncaught exception."""
    inj = FaultInjector([Fault(step=0, kind="sampler", attempts=64)])
    eng, full = _engine(tiny_hg, slots=2, slot_targets=2)
    eng.injector = inj
    reqs = [HGNNRequest(targets=np.array([1, 2])),
            HGNNRequest(targets=np.array([3, 4])),
            HGNNRequest(targets=np.array([5, 6]))]
    eng.serve(reqs)
    assert [r.status for r in reqs] == [FAILED, FAILED, OK]
    for r in reqs[:2]:
        assert "sampler failed after retries" in r.error
        assert r.logits.shape == (0, 3) and r.served.size == 0
    np.testing.assert_array_equal(reqs[2].logits, full[[5, 6]])
    rs = eng.stats()["resilience"]
    assert rs["failed_steps"] == 1 and rs["failed_requests"] == 2
    assert rs["ok_requests"] == 1
    # the failed step samples no rung
    st = eng.stats()
    assert sum(st["rung_hits"].values()) == st["steps"] - 1
    assert eng.step_log[0]["failed"] and eng.step_log[0]["rung_index"] == -1


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------


def test_degradation_controller_levels():
    res = ResilienceConfig(slo_ms=10.0, degrade_patience=2,
                           recover_patience=2)
    deg = DegradationController(res, n_rungs=3, slot_targets=4)
    assert deg.max_level == 4  # 2 rung steps + log2(4) chunk halvings
    assert (deg.chunk(), deg.rung_limit()) == (4, 2)
    for _ in range(4):
        deg.observe(0.05)  # 50ms > 10ms SLO
    assert deg.level == 2
    assert (deg.chunk(), deg.rung_limit()) == (1, 0)
    for _ in range(4):
        deg.observe(0.001)
    assert deg.level == 0
    c = deg.counters
    assert c["degrade_transitions"] == 2 and c["recover_transitions"] == 2
    assert c["max_degrade_level"] == 2
    # level can never exceed max_level (chunk floors at 1, rung at 0)
    for _ in range(40):
        deg.observe(0.05)
    assert deg.level == deg.max_level
    assert deg.chunk() == 1 and deg.rung_limit() == 0


def test_degradation_stays_inside_the_warmed_ladder(tiny_hg):
    """Injected latency breaches the SLO (slo_signal='injected' makes the
    trajectory host-independent); the engine shrinks chunks and clamps
    rungs but never leaves the warmed shape space, then recovers."""
    inj = FaultInjector([Fault(step=s, kind="latency", latency_s=0.2)
                         for s in range(2, 8)])
    res = ResilienceConfig(slo_ms=50.0, slo_signal="injected",
                           degrade_patience=2, recover_patience=2)
    eng, full = _engine(tiny_hg, res=res, injector=inj, slots=4,
                        slot_targets=2)
    reqs = _mixed_requests(24)
    eng.serve(reqs)
    st = eng.stats()
    rs = st["resilience"]
    assert all(r.status == OK for r in reqs)
    for r in reqs:
        np.testing.assert_array_equal(r.logits, full[r.targets])
    assert rs["degrade_transitions"] >= 1
    assert rs["max_degrade_level"] >= 1
    assert rs["recover_transitions"] >= 1
    assert rs["degrade_steps"] >= 1
    assert st["compiles_after_warmup"] == 0  # never left the warmed rungs
    n_rungs = len(eng.sampler.ladder)
    for e in eng.step_log:
        assert 0 <= e["rung_index"] < n_rungs
        assert e["wall_observed_s"] >= e["wall_s"]
    # degradation actually bit on the union batch at peak pressure
    assert max(e["degrade_level"] for e in eng.step_log) >= 1


def test_degraded_rung_clamp_truncates_instead_of_recompiling(tiny_hg):
    """Pressure pinned at max level: every step serves the smallest rung
    with 1-target chunks; results for served rows remain bit-exact."""
    inj = FaultInjector([Fault(step=s, kind="latency", latency_s=1.0)
                         for s in range(0, 64)])
    res = ResilienceConfig(slo_ms=1.0, slo_signal="injected",
                           degrade_patience=1, recover_patience=99)
    eng, full = _engine(tiny_hg, res=res, injector=inj, slots=4,
                        slot_targets=2)
    reqs = _mixed_requests(6)
    eng.serve(reqs)
    assert eng.stats()["compiles_after_warmup"] == 0
    assert all(r.status == OK for r in reqs)
    for r in reqs:
        np.testing.assert_array_equal(r.logits, full[r.targets])
    assert eng.step_log[-1]["degrade_level"] == eng.degrade.max_level


# ---------------------------------------------------------------------------
# partition failover
# ---------------------------------------------------------------------------


def test_partition_failover_outputs_bit_exact_vs_never_failed(tiny_hg):
    """K=4 partitioned serving loses partition 1 at step 2; the failover
    re-partitions over the 3 survivors and every request's logits are
    bit-exact the never-failed run's."""
    def run(inj):
        eng, full = _engine(tiny_hg, injector=inj, partitions=4)
        reqs = _mixed_requests(10)
        eng.serve(reqs)
        return eng, reqs

    inj = FaultInjector([Fault(step=2, kind="partition", partition=1)])
    e1, r1 = run(inj)
    e2, r2 = run(None)
    assert all(r.status == OK for r in r1)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.logits, b.logits)
    rs = e1.stats()["resilience"]
    assert rs["partition_failovers"] == 1
    assert rs["lost_partitions"] == [1]
    assert e1._serve_plan.partition.k == 3
    assert e2._serve_plan.partition.k == 4
    assert e2.stats()["resilience"]["partition_failovers"] == 0


def test_failover_with_no_survivors_raises():
    from repro.core.plan import PartitionSpec
    from repro.dist.partition import surviving_partition_spec

    spec = PartitionSpec(k=2)
    assert surviving_partition_spec(spec, [1]).k == 1
    with pytest.raises(RuntimeError, match="no surviving partitions"):
        surviving_partition_spec(spec, [0, 1])
    with pytest.raises(ValueError, match="out of range"):
        surviving_partition_spec(spec, [5])


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------


def test_stats_compiles_is_none_before_warmup(tiny_hg):
    """Regression (satellite): stats() used to report a silent ``-1``
    sentinel when warmup() never ran; it must be an explicit None."""
    eng, full = _engine(tiny_hg, warm=False)
    assert eng.stats()["compiles_after_warmup"] is None
    eng.warmup()
    eng.serve(_mixed_requests(4))
    assert eng.stats()["compiles_after_warmup"] == 0


def test_chaos_schedule_reaches_terminal_statuses_without_raising(tiny_hg):
    """The ISSUE's seeded chaos bar: sampler exceptions + a forward failure
    + latency pressure over a mixed queue -> every admissible request ends
    OK / PARTIAL / FAILED, nothing raises, counters are replay-identical."""
    def run():
        inj = FaultInjector.seeded(0, n_steps=12, sampler=2, forward=1,
                                   persistent_sampler=1, latency_steps=3,
                                   latency_s=0.2)
        res = ResilienceConfig(max_queue=32, slo_ms=50.0,
                               slo_signal="injected", deadline_ms=60_000.0)
        eng, full = _engine(tiny_hg, res=res, injector=inj)
        reqs = _mixed_requests(20) + [HGNNRequest(targets=np.zeros(0))]
        eng.serve(reqs)
        return eng, reqs

    e1, r1 = run()
    e2, r2 = run()
    assert all(r.finished for r in r1)
    assert [r.status for r in r1] == [r.status for r in r2]
    assert e1.stats()["resilience"] == e2.stats()["resilience"]
    rs = e1.stats()["resilience"]
    assert rs["retries"] > 0
    assert rs["failed_steps"] >= 1 and rs["failed_requests"] >= 1
    assert rs["ok_requests"] + rs["failed_requests"] == 21
    assert e1.stats()["compiles_after_warmup"] == 0
