"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
multi-device tests spawn subprocesses (tests/test_dist.py).

If ``hypothesis`` is unavailable (minimal CI image), a deterministic stub
covering the subset these tests use (integers / sampled_from strategies,
``given``/``settings``) is installed so property tests still run — each
``@given`` sweeps ``max_examples`` seeded draws instead of failing at import.
"""
import functools
import inspect
import sys
import types

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.hgraph import HeteroGraph


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = lambda lo, hi: _Strategy(
        lambda rng: int(rng.integers(lo, hi + 1)))
    strategies.sampled_from = lambda seq: _Strategy(
        lambda rng: seq[int(rng.integers(0, len(seq)))])
    strategies.booleans = lambda: _Strategy(lambda rng: bool(rng.integers(0, 2)))
    strategies.floats = lambda lo, hi: _Strategy(
        lambda rng: float(rng.uniform(lo, hi)))

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    draws = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **draws, **kwargs)
            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats])
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.strategies = strategies
    mod.given = given
    mod.settings = settings
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def tiny_hg() -> HeteroGraph:
    """Small deterministic bipartite-ish HG (movie/director/actor style)."""
    rng = np.random.default_rng(7)
    counts = {"M": 40, "D": 15, "A": 25}
    dims = {"M": 12, "D": 8, "A": 10}
    feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
             for t, n in counts.items()}

    def rand_rel(ns, nd, e):
        r = rng.integers(0, ns, e)
        c = rng.integers(0, nd, e)
        return sp.csr_matrix((np.ones(e, np.float32), (r, c)), shape=(ns, nd))

    md = rand_rel(40, 15, 60)
    ma = rand_rel(40, 25, 80)
    g = HeteroGraph(
        counts, feats,
        {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
         ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
        name="tiny")
    g.validate()
    return g


@pytest.fixture(scope="session")
def tiny_cfg_base():
    return dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                vocab=101, dtype="float32", param_dtype="float32",
                remat="full", attn_chunk=16)
