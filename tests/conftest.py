"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
multi-device tests spawn subprocesses (tests/test_dist.py)."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.hgraph import HeteroGraph


@pytest.fixture(scope="session")
def tiny_hg() -> HeteroGraph:
    """Small deterministic bipartite-ish HG (movie/director/actor style)."""
    rng = np.random.default_rng(7)
    counts = {"M": 40, "D": 15, "A": 25}
    dims = {"M": 12, "D": 8, "A": 10}
    feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
             for t, n in counts.items()}

    def rand_rel(ns, nd, e):
        r = rng.integers(0, ns, e)
        c = rng.integers(0, nd, e)
        return sp.csr_matrix((np.ones(e, np.float32), (r, c)), shape=(ns, nd))

    md = rand_rel(40, 15, 60)
    ma = rand_rel(40, 25, 80)
    g = HeteroGraph(
        counts, feats,
        {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
         ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
        name="tiny")
    g.validate()
    return g


@pytest.fixture(scope="session")
def tiny_cfg_base():
    return dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                vocab=101, dtype="float32", param_dtype="float32",
                remat="full", attn_chunk=16)
