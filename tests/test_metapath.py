"""Subgraph Build properties (hypothesis): adjacency correctness vs brute
force, padding invariants, instance sampling validity, sparsity monotonicity
(the paper's Fig. 6a claim)."""
import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from repro.core import metapath as mp
from repro.core.hgraph import HeteroGraph, metapath_adjacency, sparsity


def _rand_hg(seed, n1=12, n2=9, e1=20, e2=15):
    rng = np.random.default_rng(seed)
    a = sp.csr_matrix((np.ones(e1, np.float32),
                       (rng.integers(0, n1, e1), rng.integers(0, n2, e1))),
                      shape=(n1, n2))
    counts = {"X": n1, "Y": n2}
    feats = {"X": rng.standard_normal((n1, 4)).astype(np.float32),
             "Y": rng.standard_normal((n2, 3)).astype(np.float32)}
    return HeteroGraph(counts, feats,
                       {("X", "xy", "Y"): a, ("Y", "yx", "X"): a.T.tocsr()},
                       name="rand")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_metapath_adjacency_matches_bruteforce(seed):
    hg = _rand_hg(seed)
    adj = metapath_adjacency(hg, ["X", "Y", "X"]).toarray()
    a = hg.relations[("X", "xy", "Y")].toarray()
    brute = ((a @ a.T) > 0).astype(np.float32)
    np.testing.assert_array_equal(adj, brute)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), maxdeg=st.integers(1, 8))
def test_padded_subgraph_invariants(seed, maxdeg):
    hg = _rand_hg(seed)
    sub = mp.build_padded(hg, ["X", "Y", "X"], max_degree=maxdeg)
    assert sub.nbr.shape == sub.mask.shape == (12, maxdeg)
    # every masked-in neighbor must be a true metapath neighbor (or self loop)
    adj = metapath_adjacency(hg, ["X", "Y", "X"]).toarray() > 0
    np.fill_diagonal(adj, True)  # self loops added
    for u in range(12):
        for j in range(maxdeg):
            if sub.mask[u, j] > 0:
                assert adj[u, sub.nbr[u, j]], (u, j)
    # mask is a prefix (packed layout)
    for u in range(12):
        m = sub.mask[u]
        assert (np.diff(m) <= 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_csr_edges_roundtrip(seed):
    from repro.core.stages import csr_to_edges

    hg = _rand_hg(seed)
    csr = mp.build_csr(hg, ["X", "Y", "X"], add_self_loop=False)
    seg, idx = csr_to_edges(csr.indptr, csr.indices)
    adj = metapath_adjacency(hg, ["X", "Y", "X"]).toarray()
    assert len(seg) == int(adj.sum())
    for s, i in zip(seg, idx):
        assert adj[s, i] > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(1, 6))
def test_instance_enumeration_validity(seed, cap):
    hg = _rand_hg(seed)
    ib = mp.enumerate_instances(hg, ["X", "Y", "X"], max_instances=cap,
                                max_fanout=4)
    a = hg.relations[("X", "xy", "Y")].toarray() > 0
    n, i, l = ib.nodes.shape
    assert l == 3 and i == cap
    for t in range(n):
        for j in range(i):
            if ib.mask[t, j] > 0:
                x0, y, x1 = ib.nodes[t, j]
                assert x0 == t
                assert a[x0, y] and a[x1, y]


def test_sparsity_decreases_with_metapath_length():
    """Paper Fig. 6a: longer metapaths -> denser subgraphs."""
    from repro.data.synthetic import make_dblp

    hg = make_dblp()
    s2 = sparsity(metapath_adjacency(hg, ["A", "P", "A"]))
    s4 = sparsity(metapath_adjacency(hg, ["A", "P", "V", "P", "A"]))
    assert s4 <= s2


def test_stack_padded_shapes(tiny_hg):
    subs = [mp.build_padded(tiny_hg, p, max_degree=8)
            for p in (["M", "D", "M"], ["M", "A", "M"])]
    nbr, mask = mp.stack_padded(subs)
    assert nbr.shape == (2, 40, 8) and mask.shape == (2, 40, 8)
