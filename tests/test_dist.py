"""Distributed behaviour on a small forced-device mesh (subprocess so the
main test process keeps its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {**os.environ, "PYTHONPATH": "src",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.data.loader import synth_batch
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.steps import build_train_step
        from repro.train.optimizer import build_optimizer
        from repro.train.train_step import init_train_state, make_train_step

        cfg = get_reduced("granite-8b")
        shape = ShapeConfig("s", 32, 4, "train")
        mesh = make_smoke_mesh(data=2, model=4)
        built = build_train_step(cfg, shape, mesh)
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate)
        opt = build_optimizer(cfg)
        state = init_train_state(jax.random.key(0), cfg, opt)
        batch = {k: jnp.asarray(v) for k, v in synth_batch(cfg, shape, 0).items()}
        state_sh, m_sh = jitted(jax.device_put(state, built.in_shardings[0]),
                                jax.device_put(batch, built.in_shardings[1]))
        # single-device reference
        state2 = init_train_state(jax.random.key(0), cfg, opt)
        step = make_train_step(cfg, opt)
        state_ref, m_ref = jax.jit(step)(state2, batch)
        assert abs(float(m_sh["loss"]) - float(m_ref["loss"])) < 1e-3, (
            float(m_sh["loss"]), float(m_ref["loss"]))
        print("LOSS_MATCH", float(m_sh["loss"]))
    """)
    assert "LOSS_MATCH" in out


def test_dryrun_cell_on_mini_production_mesh():
    """The dry-run path (lower+compile+analysis) on a 2x4 mini mesh."""
    out = _run("""
        import jax
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.steps import build_step
        from repro.core.characterize import analyze_compiled

        cfg = get_reduced("zamba2-1.2b")
        for kind in ("train", "prefill", "decode"):
            shape = ShapeConfig("s", 64, 8, kind)
            mesh = make_smoke_mesh(data=2, model=4)
            built = build_step(cfg, shape, mesh)
            c = jax.jit(built.fn, in_shardings=built.in_shardings,
                        out_shardings=built.out_shardings,
                        donate_argnums=built.donate).lower(*built.in_specs).compile()
            rep = analyze_compiled(c, cfg=cfg, shape=shape, n_chips=8)
            assert rep["roofline"]["step_time_s"] > 0
            print("OK", kind, rep["roofline"]["bound"])
    """)
    assert out.count("OK") == 3


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoint from a 2x4 mesh restores onto a 1x4 mesh (elastic shrink)."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.launch.mesh import make_smoke_mesh
        from repro.train import checkpoint as ckpt
        from repro.train.elastic import reshard_state
        from repro.train.optimizer import build_optimizer
        from repro.train.train_step import (init_train_state, state_shardings)

        cfg = get_reduced("granite-8b")
        opt = build_optimizer(cfg)
        state = init_train_state(jax.random.key(0), cfg, opt)
        big = make_smoke_mesh(data=2, model=4)
        sh_big = state_shardings(state, opt, big)
        state_big = reshard_state(state, sh_big)
        ckpt.save(state_big, r"{tmp_path}", step=3)

        small = make_smoke_mesh(data=1, model=4)
        sh_small = state_shardings(state, opt, small)
        restored = ckpt.restore(r"{tmp_path}", state, shardings=sh_small)
        a = np.asarray(jax.tree.leaves(state)[1], np.float32)
        b = np.asarray(jax.tree.leaves(restored)[1], np.float32)
        np.testing.assert_array_equal(a, b)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_surviving_mesh_drops_pod():
    out = _run("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.train.elastic import surviving_mesh

        devs = np.array(jax.devices()).reshape(2, 2, 2)
        mesh = Mesh(devs, ("pod", "data", "model"))
        m2 = surviving_mesh(mesh, failed_pods=[0])
        assert m2.axis_names == ("data", "model")
        assert m2.devices.shape == (2, 2)
        print("SURVIVE_OK")
    """)
    assert "SURVIVE_OK" in out


def test_sharding_resolver_divisibility_guard():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.dist.sharding import resolve_spec
        from repro.launch.mesh import make_smoke_mesh
        mesh = make_smoke_mesh(data=2, model=4)
        # 15 not divisible by 4 -> replicated; 16 divisible -> sharded
        s1 = resolve_spec((8, 15), (None, "model"), mesh)
        s2 = resolve_spec((8, 16), (None, "model"), mesh)
        assert s1 == jax.sharding.PartitionSpec(None, None), s1
        assert s2 == jax.sharding.PartitionSpec(None, "model"), s2
        # unknown axis dropped ('pod' on a single-pod mesh)
        s3 = resolve_spec((8, 16), (("pod", "data"), None), mesh)
        assert s3 == jax.sharding.PartitionSpec("data", None), s3
        print("GUARD_OK")
    """)
    assert "GUARD_OK" in out
