"""HGNN slot-based continuous batching (repro.serve.engine.HGNNServeEngine).

The ISSUE's three serving invariants:

  * slot refill keeps utilization — with a mixed-size request queue, no slot
    idles while the queue is non-empty;
  * per-request results land under the right request id after the
    relabel-inverse scatter (bit-exact vs the full-graph forward when the
    fan-out covers every neighbor);
  * the recompile count after warmup is 0 — the ladder is the whole shape
    space the jitted executor ever sees.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET
from repro.serve.engine import HGNNRequest, HGNNServeEngine
from repro.serve.sampler import HGNNSampler


def _tiny_tables():
    DATASET_METAPATHS["tiny"] = [["M", "D", "M"], ["M", "A", "M"]]
    DATASET_TARGET["tiny"] = "M"


def _build(tiny_hg, model="han", fanout=64, **kw):
    _tiny_tables()
    kw = {"max_degree": 48, "max_instances": 4, "fused": True, **kw}
    cfg = HGNNConfig(model=model, dataset="tiny", hidden=16, n_heads=4,
                     n_classes=3, fanout=fanout, **kw)
    m = get_model(cfg)
    batch = m.prepare(tiny_hg)
    params = m.init(jax.random.key(0), batch)
    fn = jax.jit(m.forward)
    full = np.asarray(fn(params, batch))
    sampler = HGNNSampler(m.plan(), cfg, tiny_hg)
    return m, params, fn, full, sampler


def _mixed_requests(n, n_nodes=40, seed=3):
    rng = np.random.default_rng(seed)
    return [HGNNRequest(targets=rng.integers(
        0, n_nodes, size=int(rng.integers(1, 9)))) for _ in range(n)]


def test_slot_refill_keeps_utilization(tiny_hg):
    """step_log's queue_len is recorded after refill: whenever requests are
    still waiting, every slot must be occupied that step."""
    m, params, fn, full, sampler = _build(tiny_hg)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                          slot_targets=2, fn=fn)
    eng.warmup()
    eng.serve(_mixed_requests(16))
    assert len(eng.step_log) > 1
    for e in eng.step_log:
        if e["queue_len"] > 0:
            assert e["active_slots"] == 4, e
        assert e["active_slots"] >= 1


def test_results_land_under_the_right_request(tiny_hg):
    """fanout >= max degree + an identity-wide ladder: every request's
    logits must be BIT-EXACT the full-graph forward's rows for its ids —
    the relabel-inverse scatter keeps request identity through chunking,
    shared steps, and out-of-order slot completion."""
    m, params, fn, full, sampler = _build(tiny_hg)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                          slot_targets=2, fn=fn)
    eng.warmup()
    reqs = _mixed_requests(12)
    done = eng.serve(reqs)
    assert done is reqs
    for r in reqs:
        assert r.finished
        np.testing.assert_array_equal(r.logits, full[r.targets])


def test_zero_recompiles_after_warmup(tiny_hg):
    """Mixed request sizes sweep multiple ladder rungs; after the per-rung
    warmup the jit cache must not grow."""
    for model, kw in [("han", {}), ("rgcn", {}), ("magnn", {}),
                      ("han", {"degree_buckets": 3}),
                      ("han", {"layers": 2})]:
        m, params, fn, full, sampler = _build(tiny_hg, model=model,
                                              fanout=3, **kw)
        eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                              slot_targets=2, fn=fn)
        eng.warmup()
        eng.serve(_mixed_requests(10))
        st = eng.stats()
        assert st["compiles_after_warmup"] == 0, (model, kw, st)
        assert st["steps"] == len(eng.step_log)
        assert sum(st["rung_hits"].values()) == st["steps"]
        assert set(st["rung_hits"]) <= set(range(len(sampler.ladder)))


def test_sampled_serving_is_deterministic(tiny_hg):
    """Same queue, small fan-out (genuine subsampling): two engines produce
    identical per-request logits — sampling is precomputed + deterministic,
    so serving results are reproducible."""
    out = []
    for _ in range(2):
        m, params, fn, full, sampler = _build(tiny_hg, fanout=2)
        eng = HGNNServeEngine(m.executor, params, sampler, slots=3,
                              slot_targets=2, fn=fn)
        eng.warmup()
        reqs = _mixed_requests(8)
        eng.serve(reqs)
        out.append([r.logits for r in reqs])
    for a, b in zip(*out):
        np.testing.assert_array_equal(a, b)


def test_empty_request_terminates(tiny_hg):
    m, params, fn, full, sampler = _build(tiny_hg)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=2,
                          slot_targets=2, fn=fn)
    eng.warmup()
    reqs = [HGNNRequest(targets=np.zeros(0, np.int64)),
            HGNNRequest(targets=np.array([5, 7]))]
    eng.serve(reqs)
    assert reqs[0].logits.shape[0] == 0
    np.testing.assert_array_equal(reqs[1].logits, full[[5, 7]])


def test_slot_plan_must_fit_the_ladder(tiny_hg):
    m, params, fn, full, sampler = _build(
        tiny_hg, sample_ladder=((4, 40), (8, 40)))
    with pytest.raises(ValueError, match="slot_targets"):
        HGNNServeEngine(m.executor, params, sampler, slots=8, slot_targets=4,
                        fn=fn)


def test_oversized_request_chunks_across_steps(tiny_hg):
    """A request larger than slots*slot_targets spreads over multiple steps
    and still lands bit-exact."""
    m, params, fn, full, sampler = _build(tiny_hg)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=2,
                          slot_targets=2, fn=fn)
    eng.warmup()
    big = HGNNRequest(targets=np.arange(23))
    eng.serve([big])
    # one occupied slot contributing slot_targets=2 per step
    assert len(eng.step_log) == 12
    np.testing.assert_array_equal(big.logits, full[np.arange(23)])


# ---------------------------------------------------------------------------
# hot-feature residency: the live cache rides the serve loop untraced
# ---------------------------------------------------------------------------


def test_cached_zero_recompiles_across_rungs_and_degradation(tiny_hg):
    """The live cache is engine-level host bookkeeping keyed by global ids:
    mixed request sizes sweep the ladder rungs AND injected-latency
    degradation clamps the rung choice, and the jit cache still never grows
    after warmup — cache state is invisible to the traced shapes."""
    from repro.serve.faults import Fault, FaultInjector
    from repro.serve.resilience import ResilienceConfig

    inj = FaultInjector([Fault(step=s, kind="latency", latency_s=0.2)
                         for s in range(2, 8)])
    res = ResilienceConfig(slo_ms=50.0, slo_signal="injected",
                           degrade_patience=2, recover_patience=2)
    m, params, fn, full, sampler = _build(tiny_hg, cache_rows=8)
    eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                          slot_targets=2, fn=fn, resilience_cfg=res,
                          injector=inj)
    eng.warmup()
    reqs = _mixed_requests(24)
    eng.serve(reqs)
    st = eng.stats()
    assert st["compiles_after_warmup"] == 0
    assert len(st["rung_hits"]) >= 1
    assert st["resilience"]["max_degrade_level"] >= 1
    rd = st["residency"]
    assert rd["hits"] + rd["misses"] == rd["rows"] > 0
    assert rd["hits"] > 0  # slot chunking re-touches hot frontier rows
    for t, c in rd["per_type"].items():
        assert c["resident"] <= c["capacity"] <= 8, t
    for r in reqs:
        np.testing.assert_array_equal(r.logits, full[r.targets])


def test_cache_state_survives_partition_failover_bit_exact(tiny_hg):
    """K=4 partitioned serving loses partition 1 at step 2: the caches are
    keyed by GLOBAL vertex ids and owned by the engine, so failover cannot
    disturb them — post-recovery logits stay bit-exact vs a never-failed
    cached run, and both runs replay identical residency counters."""
    from repro.serve.faults import Fault, FaultInjector

    def run(inj):
        m, params, fn, full, sampler = _build(
            tiny_hg, partitions=4, cache_rows=8)
        eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                              slot_targets=2, fn=fn, injector=inj)
        eng.warmup()
        reqs = _mixed_requests(10)
        eng.serve(reqs)
        return eng, reqs, full

    inj = FaultInjector([Fault(step=2, kind="partition", partition=1)])
    e1, r1, full = run(inj)
    e2, r2, _ = run(None)
    assert e1.stats()["resilience"]["partition_failovers"] == 1
    assert e1._serve_plan.partition.k == 3
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.logits, b.logits)
        np.testing.assert_array_equal(a.logits, full[a.targets])
    rd1, rd2 = e1.stats()["residency"], e2.stats()["residency"]
    assert rd1 == rd2  # identical traces -> identical cache replay
    assert rd1["rows"] > 0
    # the caches themselves are untouched by the failover: same resident
    # sets in both runs
    for t in e1.caches:
        assert e1.caches[t].resident == e2.caches[t].resident
        assert e1.caches[t].pinned == set() == e2.caches[t].pinned
