"""Property tests for hot-feature residency (repro.core.residency).

The cache-coherence contract, over randomized graphs / capacities / access
traces (hypothesis, or the deterministic conftest stub on minimal CI images):

  1. bit-exactness — for every NA layout the plan can express (stacked,
     bucketed, padded per-relation, instance tables, csr edge lists), the
     remapped index tables read the cache-extended pool to exactly the rows
     the original tables read from HBM, and the ops/kernel ``cached_gather``
     paths agree bitwise with a direct gather;
  2. the hot set is the deterministic top-C of the degree ordering
     ``(count desc, id asc)``;
  3. pinned rows are never evicted from the live cache, and eviction replays
     deterministically (same trace -> same resident set + counters);
  4. conservation — ``hits + misses == rows`` (total gathered rows) on both
     the static counters and the live cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import HGNNConfig
from repro.core import residency as rsd
from repro.core.hgraph import HeteroGraph
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET

DATASET_METAPATHS["rest"] = [["M", "D", "M"], ["M", "A", "M"]]
DATASET_TARGET["rest"] = "M"


def _rand_hg(seed: int) -> HeteroGraph:
    rng = np.random.default_rng(seed)
    nm = int(rng.integers(12, 40))
    nd = int(rng.integers(5, 16))
    na = int(rng.integers(6, 20))
    counts = {"M": nm, "D": nd, "A": na}
    dims = {"M": 6, "D": 5, "A": 4}
    feats = {t: rng.standard_normal((n, dims[t])).astype(np.float32)
             for t, n in counts.items()}

    def rr(ns, nd_, e):
        r = rng.integers(0, ns, e)
        c = rng.integers(0, nd_, e)
        return sp.csr_matrix((np.ones(e, np.float32), (r, c)),
                             shape=(ns, nd_))

    md = rr(nm, nd, 3 * nm)
    ma = rr(nm, na, 3 * nm)
    g = HeteroGraph(
        counts, feats,
        {("M", "md", "D"): md, ("D", "dm", "M"): md.T.tocsr(),
         ("M", "ma", "A"): ma, ("A", "am", "M"): ma.T.tocsr()},
        name="rest")
    g.validate()
    return g


LAYOUTS = [
    ("han", {"fused": False}),          # csr edge lists
    ("han", {"fused": True}),           # stacked [P, N, K]
    ("han", {"fused": True, "degree_buckets": 3}),   # bucketed
    ("rgcn", {"fused": False}),         # per-relation csr
    ("rgcn", {"fused": True}),          # per-relation padded
    ("rgcn", {"fused": True, "degree_buckets": 3}),  # per-relation bucketed
    ("magnn", {}),                      # instance tables
]


def _cfg(model, cache_rows=0, **kw):
    kw = {"max_degree": 8, "max_instances": 4, **kw}
    return HGNNConfig(model=model, dataset="rest", hidden=16, n_heads=4,
                      n_classes=3, cache_rows=cache_rows, **kw)


# ---------------------------------------------------------------------------
# 1. bit-exactness of the remapped gathers, every layout
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(1, 64),
       case=st.sampled_from(LAYOUTS))
def test_remapped_gathers_read_exact_rows(seed, cap, case):
    """For every gather table the plan declares, the LUT-remapped indices
    address the cache-extended pool ``concat(h, h[hot])`` to bitwise the
    same rows the original indices address in ``h`` — the invariant the
    executor's residency arm rides for free."""
    model, kw = case
    hg = _rand_hg(seed)
    m0 = get_model(_cfg(model, **kw))
    b0 = m0.prepare(hg)
    m1 = get_model(_cfg(model, cache_rows=cap, **kw))
    plan = m1.plan()
    b1 = m1.prepare(hg)
    assert "residency" in b1
    hot = b1["residency"]["hot"]
    pools = {t: np.concatenate([f, np.asarray(f)[np.asarray(hot[t])]])
             for t, f in ((t, np.asarray(f))
                          for t, f in b0["feats"].items()) if t in hot}
    g0 = list(rsd._iter_gathers(plan, b0))
    g1 = list(rsd._iter_gathers(plan, b1))
    assert len(g0) == len(g1) and len(g0) > 0
    for (t0, i0, _m0), (t1, i1, _m1) in zip(g0, g1):
        assert t0 == t1
        direct = np.asarray(b0["feats"][t0])[np.asarray(i0)]
        cached = pools[t1][np.asarray(i1)]
        np.testing.assert_array_equal(direct, cached)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(1, 40),
       nd=st.integers(1, 3))
def test_cached_gather_ops_bit_exact(seed, cap, nd):
    """The kernels-layer gather (ref and Pallas-interpret) agrees bitwise
    with a direct take from the extended pool, for 1-3D index tables."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    n, d = int(rng.integers(10, 60)), int(rng.integers(4, 24))
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    c = min(cap, n)
    hot = jnp.asarray(rng.choice(n, c, replace=False).astype(np.int32))
    shape = tuple(int(rng.integers(2, 7)) for _ in range(nd))
    idx = jnp.asarray(rng.integers(0, n + c, shape).astype(np.int32))
    want = np.asarray(jnp.take(
        jnp.concatenate([table, jnp.take(table, hot, axis=0)], 0), idx,
        axis=0))
    np.testing.assert_array_equal(
        np.asarray(ref.cached_gather(table, hot, idx)), want)
    np.testing.assert_array_equal(
        np.asarray(ops.cached_gather(table, hot, idx, use_pallas=True,
                                     interpret=True)), want)


# ---------------------------------------------------------------------------
# 2. hot-set selection is the deterministic degree ordering
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(0, 80))
def test_hot_set_degree_ordered_deterministic(seed, cap):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60))
    counts = rng.integers(0, 6, n)
    hot = rsd.hot_set(counts, cap)
    assert len(hot) == min(cap, n)
    assert len(set(hot.tolist())) == len(hot)  # no duplicates
    # slot order is (count desc, id asc) ...
    key = [(-counts[r], r) for r in hot]
    assert key == sorted(key)
    # ... and nothing outside the hot set outranks anything inside it
    cold = set(range(n)) - set(hot.tolist())
    if len(hot) and cold:
        worst = max((-counts[r], r) for r in hot)
        assert all((-counts[r], r) > worst for r in cold)
    # same counts -> same hot set (replay determinism)
    np.testing.assert_array_equal(hot, rsd.hot_set(counts.copy(), cap))


# ---------------------------------------------------------------------------
# 3. live-cache policy: deterministic eviction, pins are inviolable
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(0, 12))
def test_live_cache_deterministic_and_conserving(seed, cap):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 40))
    deg = rng.integers(0, 8, n)
    trace = rng.integers(0, n, int(rng.integers(1, 200)))
    a = rsd.HotRowCache(cap, deg)
    b = rsd.HotRowCache(cap, deg)
    a.access_many(trace)
    b.access_many(trace)
    assert a.resident == b.resident and a.counters == b.counters
    c = a.counters
    assert c["hits"] + c["misses"] == c["rows"] == len(trace)
    assert len(a.resident) <= a.capacity
    # every resident row outranks every evicted-or-never-admitted accessed
    # row, OR was admitted while the cache still had room; the invariant
    # that must hold exactly: no cold accessed row outranks ALL residents
    if len(a.resident) == a.capacity and a.capacity > 0:
        floor = min(a._prio(r) for r in a.resident)
        cold = set(trace.tolist()) - a.resident
        assert all(a._prio(r) <= floor for r in cold)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(1, 8))
def test_live_cache_never_evicts_pinned(seed, cap):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 40))
    # adversarial degrees: the hammer rows outrank everything pinned
    deg = rng.integers(0, 4, n)
    cache = rsd.HotRowCache(cap, deg)
    pins = rng.choice(n, min(cap, n), replace=False)
    cache.pin(pins)
    cache.access_many(pins)  # admit the pinned rows
    admitted = set(int(r) for r in pins) & cache.resident
    deg[:] = 100  # every later candidate outranks the pinned residents
    cache.access_many(rng.integers(0, n, 120))
    assert admitted <= cache.resident  # pinned rows still resident
    cache.unpin(pins)
    cache.access_many(np.arange(n))  # now eviction may touch them
    assert len(cache.resident) <= cache.capacity


def test_live_cache_full_pin_blocks_eviction():
    deg = np.arange(6)
    cache = rsd.HotRowCache(2, deg)
    cache.pin([0, 1])
    cache.access_many([0, 1])
    assert cache.resident == {0, 1}
    cache.access_many([5, 5, 5])  # outranks both, but everything is pinned
    assert cache.resident == {0, 1} and cache.evictions == 0


# ---------------------------------------------------------------------------
# 4. conservation + determinism of the static batch counters
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.integers(1, 32),
       case=st.sampled_from(LAYOUTS))
def test_static_counters_conserve_and_replay(seed, cap, case):
    model, kw = case
    hg = _rand_hg(seed)
    m = get_model(_cfg(model, cache_rows=cap, **kw))
    b = m.prepare(hg)
    ctr = b["residency"]["counters"]
    assert ctr["hits"] + ctr["misses"] == ctr["rows"] > 0
    assert 0 <= ctr["hits"] <= ctr["rows"]
    # replay: preparing the same graph again reproduces the exact counters
    b2 = get_model(_cfg(model, cache_rows=cap, **kw)).prepare(hg)
    assert b2["residency"]["counters"] == ctr
    # hot sets are per-type degree-ordered top-C of the recount
    tables = rsd.build_tables(m.plan(), get_model(_cfg(model, **kw)).prepare(hg))
    for t, hot in b["residency"]["hot"].items():
        np.testing.assert_array_equal(
            np.asarray(hot), rsd.hot_set(tables.counts[t], cap))


def test_partition_overlay_slots_match_rank():
    """Partitioned residency: every halo-table entry carrying a cache slot
    names a hot global vertex, the slot is that vertex's rank, and the
    counters count exactly the valid halo entries."""
    hg = _rand_hg(3)
    m = get_model(_cfg("han", fused=True, cache_rows=6, partitions=3))
    plan = m.plan()
    b = m.prepare(hg)
    res = b["residency"]
    assert "hot" not in res and "hot_flat" in res
    part = b["part"]
    t = plan.target
    own = np.asarray(part["own"][t]).reshape(-1)
    slot = np.asarray(res["halo_slot"][t])
    hs = np.asarray(part["halo_src"][t])
    hm = np.asarray(part["halo_mask"][t]) > 0
    # recompute the hot set on the unpartitioned batch
    tables = rsd.build_tables(plan, get_model(_cfg("han", fused=True)).prepare(hg))
    rank = tables.rank[t]
    halo_g = own[hs.reshape(-1)].reshape(hs.shape)
    np.testing.assert_array_equal(slot, np.where(hm, rank[halo_g], -1))
    ctr = res["counters"]
    assert ctr["hits"] == int((slot >= 0).sum())
    assert ctr["rows"] == int(hm.sum())
    assert ctr["hits"] + ctr["misses"] == ctr["rows"]
    # hot rows resolve to owned flat positions that hold the same features
    hf = np.asarray(res["hot_flat"][t])
    feats_flat = np.asarray(part["feats"][t]).reshape(
        (-1,) + np.asarray(part["feats"][t]).shape[2:])
    np.testing.assert_array_equal(
        feats_flat[hf], np.asarray(hg.features[t])[tables.hot[t]])
