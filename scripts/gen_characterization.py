#!/usr/bin/env python
"""Generate docs/CHARACTERIZATION.md from the committed BENCH_hgnn.json.

The handbook reproduces the paper's table/figure story from the recorded
perf snapshot — stage time breakdown (Fig. 2), per-stage FLOPs / HBM bytes /
roofline bound (Fig. 3/4), the fused-NA and SA-epilogue optimization
snapshots (§5 guidelines), and the partitioned-execution halo-traffic sweep
(beyond-paper, `repro.dist.partition`).  Pure stdlib — no jax import — so CI
can run it in the docs job.

Usage:
    python scripts/gen_characterization.py            # (re)write the doc
    python scripts/gen_characterization.py --check    # fail on drift

`--check` regenerates the doc in memory and exits 1 if it differs from the
committed file, so the handbook can never drift from the snapshot it claims
to describe.  Regeneration is deterministic (sorted keys, fixed formats):
same BENCH_hgnn.json -> byte-identical markdown.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BENCH = ROOT / "BENCH_hgnn.json"
DOC = ROOT / "docs" / "CHARACTERIZATION.md"

HEADER = """\
# Characterization handbook

The paper's measurements — *Characterizing and Understanding HGNNs on GPUs*
(arXiv:2208.04758) — regenerated from this repo's recorded perf snapshot.

> **Generated file — do not edit.**  Source of truth is `BENCH_hgnn.json`
> (written by `benchmarks/run.py`); this page is rendered by
> `scripts/gen_characterization.py` and CI fails (`--check`) when the two
> drift apart.  Wall times are CPU-host numbers from the recording machine —
> the *shapes* (stage shares, bounds, byte ratios) are the reproducible
> story, not the absolute microseconds.
"""


def _us(v: float) -> str:
    """Fixed human format for a microsecond wall time."""
    if v >= 1e6:
        return f"{v / 1e6:.2f} s"
    if v >= 1e3:
        return f"{v / 1e3:.1f} ms"
    return f"{v:.0f} us"


def _bytes(v: float) -> str:
    if v >= 1e9:
        return f"{v / 1e9:.2f} GB"
    if v >= 1e6:
        return f"{v / 1e6:.2f} MB"
    if v >= 1e3:
        return f"{v / 1e3:.1f} kB"
    return f"{v:.0f} B"


def _stage_breakdown(data: dict) -> list:
    sb = data.get("stage_breakdown_us")
    if not sb:
        return []
    out = [
        "",
        "## Stage time breakdown (paper Fig. 2)",
        "",
        "Baseline (DGL-faithful CSR) execution, per stage, from "
        "`benchmarks/bench_stage_breakdown.py`.  The paper's claim: Neighbor "
        "Aggregation dominates (74% on average across models and datasets).",
        "",
        "| model/dataset | FP | NA | SA | NA share |",
        "| --- | --- | --- | --- | --- |",
    ]
    for case in sorted(sb):
        st = sb[case]
        total = sum(st.get(k, 0.0) for k in ("FP", "NA", "SA")) or 1.0
        cells = [(_us(st[k]) if k in st else "—") for k in ("FP", "NA", "SA")]
        share = 100.0 * st.get("NA", 0.0) / total
        out.append(f"| {case} | {cells[0]} | {cells[1]} | {cells[2]} | "
                   f"{share:.1f}% |")
    if "avg_na_share_pct" in data:
        out += ["",
                f"Average NA share: **{data['avg_na_share_pct']:.1f}%** "
                "(paper: 74%)."]
    return out


def _stage_char(data: dict) -> list:
    sc = data.get("stage_characterization")
    if not sc:
        return []
    out = [
        "",
        "## Per-stage FLOPs / HBM bytes / roofline bound (paper Fig. 3–4)",
        "",
        "From the compiled HLO of the exact stage functions the executor "
        "serves (`core/characterize.py` cost walker; arithmetic intensity = "
        "FLOPs / HBM bytes).  The paper's finding: the TB-Type NA gather is "
        "memory-bound, the DM-Type FP matmul is the only compute-leaning "
        "stage.",
        "",
        "| model/dataset | stage | FLOPs | HBM bytes | AI (FLOP/B) | bound |",
        "| --- | --- | --- | --- | --- | --- |",
    ]
    for case in sorted(sc):
        for stage in ("FP", "NA", "SA"):
            if stage not in sc[case]:
                continue
            r = sc[case][stage]
            ai = r["flops"] / r["hbm_bytes"] if r["hbm_bytes"] else 0.0
            out.append(f"| {case} | {stage} | {r['flops']:.3g} | "
                       f"{_bytes(r['hbm_bytes'])} | {ai:.3f} | "
                       f"{r['bound']} |")
    return out


def _na_fused(data: dict) -> list:
    nf = data.get("na_fused")
    if not nf:
        return []
    out = [
        "",
        "## Fused multi-head GAT-NA kernel (guideline §5: kernel fusion)",
        "",
        "One Pallas launch per `[P, N, K]` metapath stack (SDDMM + online "
        "segment-softmax + reduction tree for all heads) vs the CSR "
        "baseline's per-head kernel chain (`benchmarks/bench_na_fused.py`).",
        "",
        "| variant | wall | NA launches |",
        "| --- | --- | --- |",
    ]
    if "baseline_csr_us" in nf:
        out.append(f"| CSR baseline | {_us(nf['baseline_csr_us'])} | "
                   "per-head chain |")
    if "per_head_us" in nf:
        out.append(f"| padded, per head | {_us(nf['per_head_us'])} | "
                   f"{nf.get('na_launches_per_head', '—')} |")
    if "bucketed_us" in nf:
        out.append(f"| degree-bucketed (XLA) | {_us(nf['bucketed_us'])} | "
                   "one per bucket |")
    if "fused_us" in nf:
        out.append(f"| fused, all heads | {_us(nf['fused_us'])} | "
                   f"{nf.get('na_launches_fused', '—')} |")
    tail = []
    if nf.get("speedup_vs_baseline") is not None:
        tail.append(f"**{nf['speedup_vs_baseline']:.2f}x** vs the CSR "
                    "baseline")
    if nf.get("bucketed_speedup_vs_csr") is not None:
        tail.append("degree-bucketed layout "
                    f"**{nf['bucketed_speedup_vs_csr']:.2f}x** vs CSR "
                    "(the ROADMAP's pinned bucket-vs-baseline comparison)")
    if nf.get("kernel_max_abs_err") is not None:
        tail.append(f"kernel-vs-oracle max abs err {nf['kernel_max_abs_err']:.2e}")
    if tail:
        out += ["", "Fused speedup: " + "; ".join(tail) + "."]
    return out


def _sa_epilogue(data: dict) -> list:
    se = data.get("sa_epilogue")
    if not se:
        return []
    out = [
        "",
        "## Fused NA→SA epilogue (guideline §5: inter-stage data reuse)",
        "",
        "The semantic-score pass-1 partial accumulates inside the NA kernel "
        "while each `z` tile is in VMEM, so SA reads the `[P, N, D]` stack "
        "once instead of twice (`benchmarks/bench_sa_epilogue.py`).",
        "",
        "| variant | SA wall | SA HBM bytes |",
        "| --- | --- | --- |",
    ]
    if "two_pass_us" in se:
        out.append(f"| two-pass SA | {_us(se['two_pass_us'])} | "
                   f"{_bytes(se['two_pass_hbm_bytes'])} |")
    if "fused_us" in se:
        out.append(f"| fused epilogue | {_us(se['fused_us'])} | "
                   f"{_bytes(se['fused_hbm_bytes'])} |")
    if se.get("z_passes_saved") is not None:
        out += ["",
                f"Full `z` HBM passes saved: **{se['z_passes_saved']:.2f}** "
                f"(one pass = {_bytes(se.get('z_bytes', 0.0))})."]
    return out


def _partition(data: dict) -> list:
    pt = data.get("partition")
    if not pt:
        return []
    out = [
        "",
        "## Partitioned execution: cut ratio vs halo traffic "
        "(`repro.dist.partition`)",
        "",
        "Beyond-paper: the vertex/feature tables split into K edge-cut "
        "partitions; FP and NA run per-partition and the halo feature "
        "exchange (`gather_halo` stage) is the only communication "
        "(`benchmarks/bench_partition.py`).  More partitions cut more edges "
        "and move more halo bytes — the table is the traffic/parallelism "
        "trade every multi-chip deployment prices.",
        "",
        "| model/dataset | K | cut ratio | cut edges | halo rows | "
        "halo bytes | gather_halo | NA (per-partition) |",
        "| --- | --- | --- | --- | --- | --- | --- | --- |",
    ]

    def sort_key(case):
        base, _, kpart = case.rpartition("/k")
        return (base, int(kpart) if kpart.isdigit() else 0)

    for case in sorted(pt, key=sort_key):
        base, _, kpart = case.rpartition("/k")
        r = pt[case]
        out.append(
            f"| {base} | {kpart} | {r.get('cut_ratio', 0.0):.3f} | "
            f"{r.get('cut_edges', 0)} | {r.get('halo_rows', 0.0):.0f} | "
            f"{_bytes(r.get('halo_bytes', 0.0))} | "
            f"{_us(r['gather_halo_us']) if 'gather_halo_us' in r else '—'} | "
            f"{_us(r['NA_us']) if 'NA_us' in r else '—'} |")
    return out


def _layers(data: dict) -> list:
    ly = data.get("layers")
    if not ly:
        return []
    out = [
        "",
        "## Depth scaling: L-layer stacks (`HGNNConfig.layers`)",
        "",
        "Stacked FP→NA→SA layers over the layer-invariant host-side index "
        "tables (`benchmarks/bench_layers.py`; cf. the training "
        "characterization, arXiv:2407.11790).  Per-layer stage walls with "
        "each layer's NA share, and the partitioned arm's halo traffic — "
        "the graph-invariant halo maps re-exchange updated features every "
        "layer, so total traffic is halo-bytes × L.",
        "",
        "| model/dataset | depth | layer | FP | NA | SA | NA share |",
        "| --- | --- | --- | --- | --- | --- | --- |",
    ]

    def sort_key(case):
        base, _, dpart = case.rpartition("/L")
        return (base, int(dpart) if dpart.isdigit() else 0)

    halo_lines = []
    for case in sorted(ly, key=sort_key):
        base, _, depth = case.rpartition("/L")
        rec = ly[case]
        st = rec.get("stages_us", {})
        per_layer: dict = {}
        for name, us in st.items():
            layer, _, stage = name.rpartition(".")
            per_layer.setdefault(layer or "L1", {})[stage] = us
        for layer in sorted(per_layer):
            stages_us = per_layer[layer]
            total = sum(stages_us.get(s, 0.0)
                        for s in ("FP", "NA", "SA")) or 1.0
            cells = [(_us(stages_us[s]) if s in stages_us else "—")
                     for s in ("FP", "NA", "SA")]
            share = 100.0 * stages_us.get("NA", 0.0) / total
            out.append(f"| {base} | {depth} | {layer} | {cells[0]} | "
                       f"{cells[1]} | {cells[2]} | {share:.1f}% |")
        halo = rec.get("halo")
        if halo:
            halo_lines.append(
                f"| {base} | {depth} | {int(halo.get('k', 0))} | "
                f"{_bytes(halo.get('halo_bytes', 0.0))} | "
                f"{_bytes(halo.get('halo_bytes_total', 0.0))} |")
    if halo_lines:
        out += [
            "",
            "Partitioned arm (K edge-cut partitions): one `gather_halo` "
            "exchange per layer over the same halo maps.",
            "",
            "| model/dataset | depth | K | halo bytes / exchange | "
            "halo bytes × L |",
            "| --- | --- | --- | --- | --- |",
        ] + halo_lines
    return out


def _serving(data: dict) -> list:
    sv = data.get("serving")
    if not sv:
        return []
    out = [
        "",
        "## Request-path serving: sampled minibatches, slot batching "
        "(`repro.serve`)",
        "",
        "Beyond-paper: a fixed 32-request queue drains through the "
        "slot-based continuous-batching engine (`HGNNServeEngine`) — each "
        "step unions the active slots' targets, neighbor-samples a relabeled "
        "subgraph (`HGNNSampler`), snaps it to a shape-bucket ladder rung, "
        "and runs the same jitted stage-graph forward "
        "(`benchmarks/bench_serving.py`).  The recompile column is the "
        "ladder's whole point: 0 after warmup, gated by "
        "`benchmarks/run.py --check` along with frontier bytes and rung "
        "hits; walls and throughput are recorded but never gated.",
        "",
        "| model/dataset | slots | steps | recompiles | frontier bytes | "
        "rung hits | step wall | targets/s |",
        "| --- | --- | --- | --- | --- | --- | --- | --- |",
    ]

    def sort_key(case):
        base, _, spart = case.rpartition("/s")
        return (base, int(spart) if spart.isdigit() else 0)

    for case in sorted(sv, key=sort_key):
        base, _, slots = case.rpartition("/s")
        r = sv[case]
        hits = "; ".join(f"r{i}: {r['rung_hits'][i]}"
                         for i in sorted(r.get("rung_hits", {}),
                                         key=lambda k: int(k)))
        out.append(
            f"| {base} | {slots} | {r.get('steps', 0)} | "
            f"{r.get('recompiles', 0)} | "
            f"{_bytes(r.get('frontier_bytes', 0.0))} | {hits or '—'} | "
            f"{_us(r['step_us']) if 'step_us' in r else '—'} | "
            f"{r.get('throughput_tps', 0.0):.0f} |")
    return out


def _resilience(data: dict) -> list:
    rz = data.get("resilience")
    if not rz:
        return []
    out = [
        "",
        "## Serving resilience: seeded chaos counters "
        "(`repro.serve.resilience`)",
        "",
        "Beyond-paper: the same slot engine serves a fixed queue while a "
        "seeded `FaultInjector` (`repro.serve.faults`) drives transient + "
        "persistent exceptions, an injected-latency SLO breach (degradation "
        "shrinks per-slot chunks and clamps the rung choice *inside* the "
        "warmed ladder — recompiles stay 0 under pressure), a bounded "
        "queue that sheds overflow, and — partitioned arm — a partition "
        "loss whose failover re-partitions over the survivors "
        "(`benchmarks/bench_resilience.py`).  Every counter replays the "
        "seeded schedule exactly and is gated by `benchmarks/run.py "
        "--check` at exact equality; walls are recorded but never gated.",
        "",
        "| case | steps | ok | failed | shed | retries | degrade/recover | "
        "max level | failovers | bit-exact | step wall |",
        "| --- | --- | --- | --- | --- | --- | --- | --- | --- | --- | "
        "--- |",
    ]
    for case in sorted(rz):
        r = rz[case]
        deg = (f"{r['degrade_transitions']}/{r['recover_transitions']}"
               if "degrade_transitions" in r else "—")
        out.append(
            f"| {case} | {r.get('steps', 0)} | {r.get('ok_requests', 0)} | "
            f"{r.get('failed_requests', '—')} | {r.get('shed', '—')} | "
            f"{r.get('retries', '—')} | {deg} | "
            f"{r.get('max_degrade_level', '—')} | "
            f"{r.get('partition_failovers', 0)} | "
            f"{'yes' if r.get('bitexact') else '—'} | "
            f"{_us(r['step_us']) if 'step_us' in r else '—'} |")
    return out


def _residency(data: dict) -> list:
    rs = data.get("residency")
    if not rs:
        return []
    out = [
        "",
        "## Hot-feature residency: hit rate vs NA HBM bytes "
        "(`repro.core.residency`)",
        "",
        "Beyond-paper: the top-C highest-degree source rows per type are "
        "LUT-remapped into a cache section of the feature pool that the "
        "Pallas gather keeps VMEM-resident (`kernels/feature_cache.py`), "
        "so the memory-bound NA stage re-reads hot rows on-chip instead of "
        "from HBM (`benchmarks/bench_residency.py`).  Hit counters are "
        "deterministic plan-time quantities, gated at exact equality by "
        "`benchmarks/run.py --check`; walls are recorded, never gated.  "
        "`C` is the per-type capacity (`--cache-rows`), *rows cached* the "
        "summed hot-set size across source types; C=0 is the uncached "
        "baseline.  The fill + pool-concat overhead means a too-small "
        "cache can cost bytes until the hit mass amortizes it — the "
        "crossover is the point of the sweep.",
        "",
        "| model/dataset | C | rows cached | hit rate | hits / rows | "
        "NA HBM bytes | bytes saved | NA wall |",
        "| --- | --- | --- | --- | --- | --- | --- | --- |",
    ]

    def sort_key(case):
        base, _, cpart = case.rpartition("/c")
        return (base, int(cpart) if cpart.isdigit() else 0)

    for case in sorted(rs, key=sort_key):
        base, _, cpart = case.rpartition("/c")
        r = rs[case]
        out.append(
            f"| {base} | {cpart} | {r.get('cache_rows', 0)} | "
            f"{100.0 * r.get('hit_rate', 0.0):.1f}% | "
            f"{r.get('hits', 0)} / {r.get('rows', 0)} | "
            f"{_bytes(r.get('na_hbm_bytes', 0.0))} | "
            f"{_bytes(r.get('bytes_saved', 0.0))} | "
            f"{_us(r['na_us']) if 'na_us' in r else '—'} |")
    return out


def _overlap(data: dict) -> list:
    ov = data.get("overlap")
    if not ov:
        return []
    out = [
        "",
        "## Async stage-graph pipelining: critical path vs serial sum "
        "(`ScheduleSpec`)",
        "",
        "Beyond-paper: the serial FP→NA→SA chain relaxed to the "
        "plan-derived dependency DAG (`StageGraphExecutor.schedule_edges`) "
        "— the partitioned halo exchange runs concurrently with NA over "
        "owned rows, and the bucketed/instance NA layouts dispatch one NA "
        "stage per metapath with a single join at SA "
        "(`benchmarks/bench_overlap.py`).  Every overlapped mode is "
        "**bit-exact** vs the serial schedule; the DAG counters and the "
        "bit-exactness flag are gated by `benchmarks/run.py --check` at "
        "exact equality, the serial-sum / critical-path walls are recorded "
        "but never gated.",
        "",
        "| model/dataset/case | stages | edges | concurrent pairs | "
        "bit-exact | serial sum | critical path | saved |",
        "| --- | --- | --- | --- | --- | --- | --- | --- |",
    ]
    for case in sorted(ov):
        r = ov[case]
        ser, crit, saved = (
            (_us(r[k]) if k in r else "—")
            for k in ("serial_sum_us", "critical_path_us",
                      "overlap_saved_us"))
        out.append(
            f"| {case} | {r.get('stages', 0)} | {r.get('edges', 0)} | "
            f"{r.get('concurrent_pairs', 0)} | "
            f"{'yes' if r.get('bitexact') else 'NO'} | "
            f"{ser} | {crit} | {saved} |")
    out += [
        "",
        "The saving is the halo exchange / sibling-metapath wall hidden "
        "behind the longest concurrent stage; per-stage *exposure* "
        "(`core/characterize.py::overlap_accounting`) attributes the "
        "critical path stage-by-stage in the bench rows.",
    ]
    return out


def render(data: dict) -> str:
    lines = [HEADER]
    lines += _stage_breakdown(data)
    lines += _stage_char(data)
    lines += _na_fused(data)
    lines += _sa_epilogue(data)
    lines += _partition(data)
    lines += _layers(data)
    lines += _serving(data)
    lines += _resilience(data)
    lines += _residency(data)
    lines += _overlap(data)
    lines += [
        "",
        "## Regenerating",
        "",
        "```bash",
        "# refresh the snapshot (stage breakdown + NA/SA fusion + partition",
        "# + depth sweep + request-path serving + chaos counters + residency",
        "# + async stage-graph overlap)",
        "PYTHONPATH=src:. python benchmarks/run.py bench_stage_breakdown \\",
        "    bench_na_fused bench_sa_epilogue bench_partition bench_layers \\",
        "    bench_serving bench_resilience bench_residency bench_overlap",
        "# re-render this page",
        "python scripts/gen_characterization.py",
        "```",
        "",
    ]
    return "\n".join(lines)


def main() -> int:
    data = json.loads(BENCH.read_text())
    text = render(data)
    if "--check" in sys.argv[1:]:
        if not DOC.exists():
            print(f"MISSING  {DOC.relative_to(ROOT)} "
                  "(run scripts/gen_characterization.py)")
            return 1
        if DOC.read_text() != text:
            print(f"DRIFT    {DOC.relative_to(ROOT)} does not match "
                  f"{BENCH.name}; run scripts/gen_characterization.py")
            return 1
        print(f"characterization handbook OK ({DOC.relative_to(ROOT)})")
        return 0
    DOC.write_text(text)
    print(f"wrote {DOC.relative_to(ROOT)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
