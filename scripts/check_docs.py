#!/usr/bin/env python
"""Docs check: every repo path referenced in README.md / docs/ARCHITECTURE.md
/ docs/CHARACTERIZATION.md must exist (CI fails when docs drift from the
tree; the CHARACTERIZATION handbook additionally has its own content drift
check, scripts/gen_characterization.py --check).

A "path reference" is any backtick-quoted or code-block token that looks like
a repo-relative file or directory (contains a '/' or a known suffix and no
spaces). Command words, flags and URLs are ignored.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/ARCHITECTURE.md", "docs/CHARACTERIZATION.md"]

# `...`-quoted tokens; inside them, path-looking pieces
INLINE = re.compile(r"`([^`\n]+)`")
PATHISH = re.compile(r"^[\w./{},-]+$")
SKIP_PREFIXES = ("http", "--", "-m", "python", "PYTHONPATH", "XLA_FLAGS")


def expand_braces(tok: str):
    """src/a/{b,c}.py -> src/a/b.py, src/a/c.py (one brace group)."""
    m = re.search(r"\{([^{}]*)\}", tok)
    if not m:
        return [tok]
    out = []
    for part in m.group(1).split(","):
        out.extend(expand_braces(tok[: m.start()] + part.strip() + tok[m.end():]))
    return out


def candidate_paths(text: str):
    for tok in INLINE.findall(text):
        tok = tok.strip().rstrip(".,;:")
        if not PATHISH.match(tok) or tok.startswith(SKIP_PREFIXES):
            continue
        if "/" not in tok and not tok.endswith((".py", ".md", ".yml", ".sh")):
            continue
        if tok.endswith("()"):  # function refs aren't files
            continue
        yield from expand_braces(tok)


def main() -> int:
    missing = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            missing.append((doc, "<the doc itself>"))
            continue
        for tok in candidate_paths(path.read_text()):
            # docs may refer to files repo-relative ("src/repro/nn/mlp.py"),
            # src-relative ("repro/dist") or package-relative ("nn/mlp.py");
            # a bare filename ("segment_spmm.py") matches anywhere in-tree
            roots = (ROOT, ROOT / "src", ROOT / "src" / "repro")
            if any((r / tok).exists() for r in roots):
                continue
            if "/" not in tok and any(ROOT.rglob(tok)):
                continue
            missing.append((doc, tok))
    if missing:
        for doc, tok in missing:
            print(f"MISSING  {doc}: {tok}")
        return 1
    print(f"docs check OK ({', '.join(DOCS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
