"""Regenerate the EXPERIMENTS.md roofline tables from results/dryrun."""
import glob, json, sys

def table(mesh):
    rows = []
    for p in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(p))
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | {rl['bound']} | "
            f"{rl['useful_flops_ratio']:.3f} | {rl['mfu_proxy']:.4f} | "
            f"{r['memory']['peak_device_gib']:.2f} |")
    return rows

for mesh in ("16x16", "2x16x16"):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bound | useful | mfu_proxy | peak GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    print("\n".join(table(mesh)))
