"""Quickstart: build a heterogeneous graph, run HAN through the paper's four
stages, train it for a few steps, and print the per-stage characterization.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core.characterize import analyze_hlo_text
from repro.core.models import get_model
from repro.data.synthetic import make_dataset


def main():
    # ---- Stage 1: Subgraph Build (host, scipy) ----
    hg = make_dataset("imdb")
    print(f"IMDB-like HG: {hg.node_counts}, {hg.n_edges} edges")
    cfg = HGNNConfig(model="han", dataset="imdb", hidden=64, n_heads=8,
                     n_classes=4, fused=True, max_degree=32)
    model = get_model(cfg)
    batch = model.prepare(hg)
    params = model.init(jax.random.key(0), batch)

    # ---- inference through FP -> NA -> SA ----
    fwd = jax.jit(lambda p: model.forward(p, batch))
    logits = fwd(params)
    print(f"forward: logits {logits.shape}")

    # ---- a few training steps ----
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 4, logits.shape[0]))

    def loss_fn(p):
        lg = model.forward(p, batch)
        lse = jax.nn.logsumexp(lg, -1)
        return (lse - jnp.take_along_axis(lg, labels[:, None], -1)[:, 0]).mean()

    step = jax.jit(jax.value_and_grad(loss_fn))
    for i in range(5):
        t0 = time.time()
        loss, g = step(params)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        print(f"step {i}: loss {float(loss):.4f}  ({(time.time()-t0)*1e3:.0f} ms)")

    # ---- the paper's contribution: kernel-class characterization ----
    rep = analyze_hlo_text(fwd.lower(params).compile().as_text())
    print("\nkernel-class breakdown (paper Fig. 3 analogue):")
    tot = rep["total_hbm_bytes"]
    for cls, by in sorted(rep["hbm_bytes_by_class"].items()):
        print(f"  {cls:5s}: {by/1e6:9.1f} MB HBM "
              f"({100*by/tot:4.1f}%)  flops={rep['flops_by_class'].get(cls, 0):.3g}")


if __name__ == "__main__":
    main()
