"""Batched serving example: prefill + KV-cache decode with the slot engine,
plus an enc-dec (seamless-style) decode loop.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.nn import encdec as ed
from repro.nn.transformer import init_lm_params
from repro.serve.engine import Request, ServeEngine


def decoder_only():
    cfg = get_reduced("granite-8b")
    params = init_lm_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                    max_tokens=12, temperature=0.8) for _ in range(6)]
    t0 = time.time()
    done = engine.generate(reqs)
    toks = sum(len(r.out_tokens) for r in done)
    print(f"decoder-only: {toks} tokens in {time.time()-t0:.2f}s")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: {r.out_tokens}")


def encoder_decoder():
    cfg = get_reduced("seamless-m4t-medium")
    params = ed.init_encdec_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(1)
    B, Ss = 2, 24
    frames = jnp.asarray(rng.standard_normal((B, Ss, cfg.d_model)) * 0.3,
                         jnp.float32)
    bos = jnp.zeros((B, 1), jnp.int32)
    logits, caches = ed.encdec_prefill(params, cfg, frames, bos)
    full = ed.init_encdec_caches(cfg, B, 16, Ss)
    caches = {k: jax.lax.dynamic_update_slice(
        full[k], caches[k].astype(full[k].dtype), (0,) * full[k].ndim)
        for k in full}
    tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
    outs = [tok]
    step = jax.jit(lambda p, t, c, pos: ed.encdec_decode_step(p, cfg, t, c, pos))
    for t in range(1, 10):
        logits, caches = step(params, tok, caches, jnp.int32(t))
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    seq = jnp.concatenate(outs, 1)
    print(f"enc-dec translate-style decode: {np.asarray(seq).tolist()}")


if __name__ == "__main__":
    decoder_only()
    encoder_decoder()
