"""Reproduce the paper's characterization for any HGNN workload:

    PYTHONPATH=src python examples/characterize_hgnn.py --model han --dataset acm

Prints the Fig. 2 stage breakdown (measured wall time), the Fig. 3
kernel-class mix, and the Fig. 4 roofline placement per stage.
"""
import argparse

import jax

from benchmarks.hgnn_setup import build, stage_fns
from benchmarks.common import time_jitted
from repro.core.characterize import HBM_BW, PEAK_FLOPS, analyze_hlo_text

RIDGE = PEAK_FLOPS / HBM_BW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="han",
                    choices=["han", "rgcn", "magnn"])
    ap.add_argument("--dataset", default="acm",
                    choices=["imdb", "acm", "dblp"])
    ap.add_argument("--fused", action="store_true",
                    help="optimized path (stacked subgraphs, concat-free SA)")
    args = ap.parse_args()

    cfg, m, params, batch = build(args.model, args.dataset, fused=args.fused)
    fns = stage_fns(m, params, batch)

    print(f"== {args.model} on {args.dataset} "
          f"({'optimized' if args.fused else 'baseline'} path) ==")
    times = {}
    for stage in ("FP", "NA", "SA"):
        fn, fargs = fns[stage]
        times[stage] = time_jitted(fn, *fargs)
    total = sum(times.values())
    print("\nFig.2 stage breakdown (CPU wall):")
    for stage, t in times.items():
        print(f"  {stage}: {t/1e3:9.2f} ms  ({100*t/total:4.1f}%)")

    print("\nFig.3 kernel classes / Fig.4 roofline per stage (TPU v5e model):")
    for stage in ("FP", "NA", "SA"):
        fn, fargs = fns[stage]
        rep = analyze_hlo_text(fn.lower(*fargs).compile().as_text())
        fl, by = rep["total_flops"], max(rep["total_hbm_bytes"], 1.0)
        ai = fl / by
        mix = " ".join(f"{c}={int(100*v/max(rep['total_hbm_bytes'],1))}%"
                       for c, v in sorted(rep["hbm_bytes_by_class"].items()))
        print(f"  {stage}: AI={ai:6.2f} FLOP/B "
              f"[{'compute' if ai > RIDGE else 'memory'}-bound]  bytes: {mix}")


if __name__ == "__main__":
    main()
