"""End-to-end LM training driver (deliverable b): the full train substrate
(optimizer, schedule, grad-accum, async checkpointing, prefetching loader)
driving a smollm-family model for a few hundred steps.

CPU demo (default — a width-reduced smollm so 200 steps finish in minutes;
the loss floor is ln(vocab) since the synthetic stream is random):
    PYTHONPATH=src python examples/train_lm.py

The ~100M+ run (full smollm-360m) is the same code path on real hardware:
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.data.loader import PrefetchLoader
from repro.train import checkpoint as ckpt
from repro.train.optimizer import build_optimizer
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("smollm-360m")
    else:
        # ~100M-scale stand-in that trains at CPU speed: keep smollm's shape
        # family, shrink width/depth
        cfg = get_reduced("smollm-360m").replace(
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
            d_ff=512, vocab=2048)
    shape = ShapeConfig("train", args.seq_len, args.batch, "train")
    opt = build_optimizer(cfg, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    state = init_train_state(jax.random.key(0), cfg, opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"training {cfg.name}-derived model: {n_params/1e6:.1f}M params")

    saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
    loader = PrefetchLoader(cfg, shape)
    losses = []
    try:
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            if (i + 1) % 20 == 0:
                dt = (time.time() - t0) / 20
                tok_s = args.batch * args.seq_len / dt
                print(f"step {i+1:4d} loss {losses[-1]:.4f} "
                      f"({dt*1e3:.0f} ms/step, {tok_s:.0f} tok/s)")
                t0 = time.time()
            if (i + 1) % 100 == 0:
                saver.submit(state, i + 1)
        saver.submit(state, args.steps)
    finally:
        loader.close()
        saver.close()
    print(f"loss: first20={sum(losses[:20])/20:.4f} "
          f"last20={sum(losses[-20:])/20:.4f} (should decrease)")


if __name__ == "__main__":
    main()
