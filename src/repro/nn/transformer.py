"""Decoder-only transformer composition: dense / MoE / SSM / hybrid / VLM.

Layers are grouped into homogeneous runs (``layer_runs``) and each run is a
``lax.scan`` over stacked params — HLO stays one-block-sized regardless of
depth (critical for CPU-compiled 512-device dry-runs of 80-layer models).
zamba2's shared attention block has ONE param set referenced at every
application (weight sharing), each application with its own KV cache.

Public entry points:
  init_lm_params / lm_loss (train)   lm_prefill / lm_decode_step (serve)
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import BATCH, MODEL, shard
from repro.nn import attention as attn
from repro.nn.mlp import init_mlp, mlp_block
from repro.nn.moe import init_moe, moe_block
from repro.nn.norm import init_rmsnorm, rmsnorm
from repro.nn.ssm import (
    MambaCache,
    init_mamba2,
    init_mamba_cache,
    mamba2_block,
)


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------


def layer_runs(cfg: ModelConfig) -> List[Tuple[str, int]]:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return [("attn", cfg.n_layers)]
    if fam == "moe":
        return [("moe", cfg.n_layers)]
    if fam == "ssm":
        return [("mamba", cfg.n_layers)]
    if fam == "hybrid":
        runs: List[Tuple[str, int]] = []
        period = cfg.shared_attn_period or cfg.n_layers
        left = cfg.n_layers
        while left > 0:
            k = min(period, left)
            runs.append(("mamba", k))
            left -= k
            if left > 0 or k == period:
                runs.append(("shared_attn", 1))
        return runs
    raise ValueError(f"layer_runs: unsupported family {fam}")


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# per-kind blocks. every block: (params, cfg, x, positions) -> (x, aux)
# ---------------------------------------------------------------------------


def _init_block(rng, cfg: ModelConfig, kind: str) -> Dict:
    d = cfg.d_model
    k1, k2 = jax.random.split(rng)
    if kind in ("attn", "shared_attn"):
        return {
            "ln1": init_rmsnorm(d),
            "attn": attn.init_attention(k1, cfg),
            "ln2": init_rmsnorm(d),
            "mlp": init_mlp(k2, d, cfg.d_ff, cfg.n_layers, cfg.param_dtype),
        }
    if kind == "moe":
        return {
            "ln1": init_rmsnorm(d),
            "attn": attn.init_attention(k1, cfg),
            "ln2": init_rmsnorm(d),
            "moe": init_moe(k2, d, cfg.moe, cfg.n_layers, cfg.param_dtype),
        }
    if kind == "mamba":
        return {"ln1": init_rmsnorm(d), "mamba": init_mamba2(k1, d, cfg.ssm, cfg.n_layers, cfg.param_dtype)}
    raise ValueError(kind)


def _seq_shard(cfg: ModelConfig, x):
    """Sequence-parallel residual constraint (see ModelConfig docstring)."""
    if cfg.seq_shard_activations:
        return shard(x, BATCH, MODEL, None)
    return x


def _apply_block(p, cfg: ModelConfig, kind: str, x, positions, causal=True):
    aux = jnp.zeros((), jnp.float32)
    ss = lambda h: _seq_shard(cfg, h)
    sseq = cfg.seq_shard_activations
    if kind in ("attn", "shared_attn"):
        x = x + attn.attention_block(p["attn"], cfg, ss(rmsnorm(p["ln1"], x, cfg.norm_eps)), positions, causal)
        x = x + mlp_block(p["mlp"], ss(rmsnorm(p["ln2"], x, cfg.norm_eps)), seq_shard=sseq)
    elif kind == "moe":
        x = x + attn.attention_block(p["attn"], cfg, ss(rmsnorm(p["ln1"], x, cfg.norm_eps)), positions, causal)
        h, aux = moe_block(p["moe"], ss(rmsnorm(p["ln2"], x, cfg.norm_eps)), cfg.moe)
        x = x + h
    elif kind == "mamba":
        h, _ = mamba2_block(p["mamba"], cfg, ss(rmsnorm(p["ln1"], x, cfg.norm_eps)))
        x = x + h
    else:
        raise ValueError(kind)
    return _seq_shard(cfg, x), aux


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_lm_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.vocab
    keys = jax.random.split(rng, 8)
    pd = jnp.dtype(cfg.param_dtype)
    params: Dict = {
        "embed": (jax.random.normal(keys[0], (v, d)) * 0.02).astype(pd),
        "ln_f": init_rmsnorm(d),
        "runs": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (d, v)) / np.sqrt(d)).astype(pd)
    shared_done = False
    for i, (kind, count) in enumerate(layer_runs(cfg)):
        kr = jax.random.fold_in(keys[2], i)
        if kind == "shared_attn":
            if not shared_done:
                params["shared"] = _init_block(kr, cfg, "shared_attn")
                shared_done = True
            params["runs"].append({})  # placeholder (weights live in 'shared')
        else:
            blocks = [_init_block(jax.random.fold_in(kr, j), cfg, kind) for j in range(count)]
            params["runs"].append(jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
                                  if count > 1 else jax.tree.map(lambda x: x[None], blocks[0]))
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embeds):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if extra_embeds is not None:  # modality frontend stub (vlm/audio)
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return shard(x, BATCH, None, None)


def lm_backbone(params, cfg: ModelConfig, x, positions, causal=True,
                collect_kv: bool = False):
    """Run all layer runs. Returns (hidden, aux_sum, kv_caches|None)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = [] if collect_kv else None
    for (kind, count), stack in zip(layer_runs(cfg), params["runs"]):
        if kind == "shared_attn":
            p = params["shared"]
            if collect_kv:
                h, kv = attn.attention_block(
                    p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
                    causal, return_kv=True)
                x = x + h
                x = x + mlp_block(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
                caches.append({"k": kv[0][None], "v": kv[1][None]})
            else:
                x, _ = _apply_block(p, cfg, "shared_attn", x, positions, causal)
            continue

        if collect_kv and kind in ("attn", "moe"):
            def body(xc, p, _kind=kind):
                h, kv = attn.attention_block(
                    p["attn"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps), positions,
                    causal, return_kv=True)
                xc = xc + h
                if _kind == "attn":
                    xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln2"], xc, cfg.norm_eps))
                else:
                    hh, _ = moe_block(p["moe"], rmsnorm(p["ln2"], xc, cfg.norm_eps), cfg.moe)
                    xc = xc + hh
                return xc, {"k": kv[0], "v": kv[1]}

            x, kvs = jax.lax.scan(_remat(body, cfg), x, stack)
            caches.append(kvs)
        elif collect_kv and kind == "mamba":
            def mbody(xc, p):
                h, cache = mamba2_block(
                    p["mamba"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps),
                    return_state=True)
                return xc + h, cache

            x, st = jax.lax.scan(_remat(mbody, cfg), x, stack)
            caches.append(st)
        else:
            def body2(xc, p, _kind=kind):
                xn, aux = _apply_block(p, cfg, _kind, xc, positions, causal)
                return xn, aux

            x, auxs = jax.lax.scan(_remat(body2, cfg), x, stack)
            aux_total = aux_total + auxs.sum()
    return x, aux_total, caches


def lm_logits(params, cfg: ModelConfig, h):
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head  # [B, S, V] (sharded V over 'model')


def lm_forward(params, cfg: ModelConfig, tokens, extra_embeds=None):
    b, s = tokens.shape
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    positions = jnp.arange(x.shape[1])
    h, aux, _ = lm_backbone(params, cfg, x, positions)
    if extra_embeds is not None:
        h = h[:, extra_embeds.shape[1]:]
    return lm_logits(params, cfg, h), aux


# ---------------------------------------------------------------------------
# loss (sequence-chunked cross-entropy: never materializes [B,S,V] in fp32)
# ---------------------------------------------------------------------------


def _ce_chunk(h_c, head, labels_c, mask_c):
    logits = (h_c @ head).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    return (((lse - gold) * mask_c).sum(), mask_c.sum())


def chunked_ce(h, head, labels, mask, chunk_tokens: int):
    b, s, d = h.shape
    c = max(1, min(s, chunk_tokens))
    while s % c:
        c -= 1
    nc = s // c
    hs = jnp.moveaxis(h.reshape(b, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

    body = jax.checkpoint(
        lambda carry, xs: ((carry[0] + _ce_chunk(xs[0], head, xs[1], xs[2])[0],
                            carry[1] + xs[2].sum()), None),
        policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg: ModelConfig, batch: Dict):
    """batch: tokens [B,S], labels [B,S] (-1 = pad), optional vision/audio embeds."""
    tokens = batch["tokens"]
    extra = batch.get("extra_embeds")
    x = _embed_inputs(params, cfg, tokens, extra)
    positions = jnp.arange(x.shape[1])
    h, aux, _ = lm_backbone(params, cfg, x, positions)
    if extra is not None:
        h = h[:, extra.shape[1]:]
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    v = cfg.vocab
    chunk_tokens = max(8, int(2 ** 24 / max(v, 1)))
    loss = chunked_ce(h, head, jnp.maximum(labels, 0), mask, chunk_tokens)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def init_kv_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree skeleton (zeros) for decode. Matches lm_decode_step."""
    dh = cfg.resolved_head_dim
    h, kvh = attn._heads(cfg)
    dt = jnp.dtype(cfg.dtype)
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    caches = []
    for kind, count in layer_runs(cfg):
        if kind in ("attn", "moe", "shared_attn"):
            caches.append({
                "k": jnp.zeros((count, batch, s, kvh, dh), dt),
                "v": jnp.zeros((count, batch, s, kvh, dh), dt),
            })
        else:  # mamba
            c0 = init_mamba_cache(cfg, batch, dt)
            caches.append(MambaCache(*[
                jnp.broadcast_to(f[None], (count,) + f.shape) for f in c0]))
    return caches


def graft_prefill_caches(cfg: ModelConfig, skeleton, prefill, t0: int):
    """Place prefill KV (length t0) into decode cache skeletons.

    Handles the sliding-window ring buffer: slot r holds the newest prompt
    position p ≡ r (mod W); slots with no valid position stay zero (they are
    masked by kv_len until overwritten).
    """
    out = []
    for (kind, count), sk, pf in zip(layer_runs(cfg), skeleton, prefill):
        if isinstance(pf, MambaCache):
            out.append(pf)
            continue
        smax = sk["k"].shape[2]
        if not cfg.sliding_window:
            zeros = (0,) * sk["k"].ndim
            out.append({
                "k": jax.lax.dynamic_update_slice(sk["k"], pf["k"].astype(sk["k"].dtype), zeros),
                "v": jax.lax.dynamic_update_slice(sk["v"], pf["v"].astype(sk["v"].dtype), zeros),
            })
            continue
        w = smax
        r = jnp.arange(w)
        p = (t0 - 1) - ((t0 - 1 - r) % w)
        valid = (p >= 0) & (p > t0 - 1 - w)
        src = jnp.clip(p, 0, t0 - 1)
        def ring(buf, skbuf):
            g = jnp.take(buf, src, axis=2).astype(skbuf.dtype)
            return jnp.where(valid[None, None, :, None, None], g, 0)
        out.append({"k": ring(pf["k"], sk["k"]), "v": ring(pf["v"], sk["v"])})
    return out


def lm_prefill(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """Full-sequence forward returning last-position logits + caches."""
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    positions = jnp.arange(x.shape[1])
    h, _, caches = lm_backbone(params, cfg, x, positions, collect_kv=True)
    logits = lm_logits(params, cfg, h[:, -1:])
    return logits, caches


def lm_decode_step(params, cfg: ModelConfig, token, caches, pos):
    """token [B,1] int32; caches from init_kv_caches/prefill; pos [] int32."""
    x = _embed_inputs(params, cfg, token, None)  # [B,1,d]
    new_caches = []
    for (kind, count), stack, cache in zip(layer_runs(cfg), params["runs"], caches):
        if kind in ("attn", "moe"):
            def body(xc, xs, _kind=kind):
                p, ck, cv = xs
                h, nk, nv = attn.decode_attention_block(p["attn"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps), ck, cv, pos)
                xc = xc + h
                if _kind == "attn":
                    xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln2"], xc, cfg.norm_eps))
                else:
                    hh, _ = moe_block(p["moe"], rmsnorm(p["ln2"], xc, cfg.norm_eps), cfg.moe)
                    xc = xc + hh
                return xc, (nk, nv)

            x, (nk, nv) = jax.lax.scan(body, x, (stack, cache["k"], cache["v"]))
            new_caches.append({"k": nk, "v": nv})
        elif kind == "shared_attn":
            p = params["shared"]
            h, nk, nv = attn.decode_attention_block(
                p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                cache["k"][0], cache["v"][0], pos)
            x = x + h
            x = x + mlp_block(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
            new_caches.append({"k": nk[None], "v": nv[None]})
        else:  # mamba
            def mbody(xc, xs):
                p, c = xs
                h, ncache = mamba2_block(p["mamba"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps),
                                         cache=c)
                return xc + h, ncache

            x, ncache = jax.lax.scan(mbody, x, (stack, cache))
            new_caches.append(ncache)
    logits = lm_logits(params, cfg, x)
    return logits, new_caches
