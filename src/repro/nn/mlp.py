"""SwiGLU MLP with tensor parallelism over 'model' (Megatron layout:
up/gate column-sharded, down row-sharded -> one all-reduce per block)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import BATCH, MODEL, shard


def init_mlp(rng: jax.Array, d: int, d_ff: int, n_layers: int, param_dtype) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    pd = jnp.dtype(param_dtype)
    s = 1.0 / np.sqrt(d)
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * s).astype(pd),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s).astype(pd),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * s / np.sqrt(2 * n_layers)).astype(pd),
    }


def mlp_block(params: Dict, x: jax.Array, seq_shard: bool = False) -> jax.Array:
    g = shard(x @ params["w_gate"], BATCH, None, MODEL)
    u = shard(x @ params["w_up"], BATCH, None, MODEL)
    h = jax.nn.silu(g) * u
    out = h @ params["w_down"]
    # sequence-parallel epilogue: reduce-scatter instead of all-reduce
    return shard(out, BATCH, MODEL if seq_shard else None, None)
