"""Mamba2 — SSD (state-space duality) block, chunked TPU-friendly form.

The SSD scan is the paper-guideline (d) workload par excellence: a reduction
tree over chunks.  Within a chunk the recurrence is expressed as dense
matmuls (MXU); across chunks a short ``lax.scan`` carries the [B,H,hd,N]
state.  This is the TPU-native mapping of the recurrence (no GPU-style
parallel scan over single steps).

Projections are SPLIT per segment (z / x / B / C / dt) instead of one fused
in_proj so each gets the right sharding: z/x column-shard over 'model'
(d_inner is head-major), B/C/dt replicated (tiny).  The depthwise conv is
likewise split (conv_x sharded, conv_B/conv_C replicated).

Oracle for tests: :func:`ssd_sequential` (per-step recurrence).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.dist.sharding import BATCH, MODEL, shard
from repro.nn.norm import rmsnorm


def dims(d_model: int, ssm: SSMConfig) -> Tuple[int, int, int]:
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads, ssm.d_state


def init_mamba2(rng: jax.Array, d_model: int, ssm: SSMConfig, n_layers: int,
                param_dtype) -> Dict:
    di, nh, n = dims(d_model, ssm)
    keys = jax.random.split(rng, 6)
    pd = jnp.dtype(param_dtype)
    s = 1.0 / np.sqrt(d_model)
    return {
        "w_z": (jax.random.normal(keys[0], (d_model, di)) * s).astype(pd),
        "w_x": (jax.random.normal(keys[1], (d_model, di)) * s).astype(pd),
        "w_B": (jax.random.normal(keys[2], (d_model, n)) * s).astype(pd),
        "w_C": (jax.random.normal(keys[3], (d_model, n)) * s).astype(pd),
        "w_dt": (jax.random.normal(keys[4], (d_model, nh)) * s).astype(pd),
        "conv_x": (jax.random.normal(keys[5], (ssm.d_conv, di)) * 0.2).astype(pd),
        "conv_B": jnp.zeros((ssm.d_conv, n), pd).at[-1].set(1.0),
        "conv_C": jnp.zeros((ssm.d_conv, n), pd).at[-1].set(1.0),
        "conv_bias": jnp.zeros((di,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "norm_g": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(keys[0], (di, d_model)) * s / np.sqrt(2 * n_layers)).astype(pd),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias=None) -> jax.Array:
    """Depthwise causal conv via shifted adds. x [B,S,C], w [K,C]."""
    k = w.shape[0]
    out = x * w[-1]
    for j in range(k - 1):
        sh = k - 1 - j
        out = out + jnp.pad(x, ((0, 0), (sh, 0), (0, 0)))[:, : x.shape[1]] * w[j]
    if bias is not None:
        out = out + bias
    return out


def _conv_step(buf: jax.Array, x_new: jax.Array, w: jax.Array, bias=None):
    """Single-step conv from a [B, K-1, C] trailing buffer. Returns (y [B,1,C], new_buf)."""
    full = jnp.concatenate([buf, x_new], axis=1)  # [B, K, C]
    y = (full * w).sum(axis=1, keepdims=True)
    if bias is not None:
        y = y + bias
    return y, full[:, 1:]


def ssd_chunked(
    x: jax.Array,  # [B, S, NH, HD]
    dt: jax.Array,  # [B, S, NH] (post-softplus)
    a_neg: jax.Array,  # [NH] negative decay rate (-exp(A_log))
    b_proj: jax.Array,  # [B, S, N]
    c_proj: jax.Array,  # [B, S, N]
    chunk: int,
    init_state: jax.Array = None,  # [B, NH, HD, N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B,S,NH,HD], final_state)."""
    bsz, s, nh, hd = x.shape
    n = b_proj.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    f32 = jnp.float32
    xc = jnp.moveaxis(x.reshape(bsz, nc, L, nh, hd), 1, 0).astype(f32)  # [nc,B,L,NH,HD]
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, L, nh), 1, 0).astype(f32)
    bc = jnp.moveaxis(b_proj.reshape(bsz, nc, L, n), 1, 0).astype(f32)
    cc = jnp.moveaxis(c_proj.reshape(bsz, nc, L, n), 1, 0).astype(f32)

    if init_state is None:
        init_state = jnp.zeros((bsz, nh, hd, n), f32)

    def body(state, inp):
        xch, dch, bch, cch = inp  # [B,L,NH,HD], [B,L,NH], [B,L,N], [B,L,N]
        aa = dch * a_neg  # [B,L,NH] log-decay per step (negative)
        cum = jnp.cumsum(aa, axis=1)  # [B,L,NH]
        cum_h = jnp.moveaxis(cum, -1, 1)  # [B,NH,L]
        # intra-chunk: masked decay matrix [B,NH,L,L]
        dec = jnp.exp(cum_h[:, :, :, None] - cum_h[:, :, None, :])
        mask = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(mask, dec, 0.0)
        cb = jnp.einsum("btn,bsn->bts", cch, bch)  # [B,L,L] (heads share B/C)
        dts = jnp.moveaxis(dch, -1, 1)  # [B,NH,L] (source dt)
        m = cb[:, None] * dec * dts[:, :, None, :]  # [B,NH,L,L]
        x_h = jnp.moveaxis(xch, 2, 1)  # [B,NH,L,HD]
        y_intra = jnp.einsum("bhts,bhsd->bhtd", m, x_h)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("btn,bhdn->bhtd", cch, state) * jnp.exp(cum_h)[..., None]
        # state update
        total = cum_h[:, :, -1]  # [B,NH]
        w_src = jnp.exp(total[:, :, None] - cum_h) * dts  # [B,NH,L]
        s_in = jnp.einsum("bhs,bhsd,bsn->bhdn", w_src, x_h, bch)
        state = jnp.exp(total)[:, :, None, None] * state + s_in
        y = y_intra + y_inter  # [B,NH,L,HD]
        return state, jnp.moveaxis(y, 1, 2)  # [B,L,NH,HD]

    state, ys = jax.lax.scan(body, init_state, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, hd)
    return y.astype(x.dtype), state


def ssd_sequential(x, dt, a_neg, b_proj, c_proj, init_state=None):
    """Per-step oracle: S_t = exp(dt_t a) S_{t-1} + dt_t x_t (x) B_t ; y = C_t.S_t."""
    bsz, s, nh, hd = x.shape
    n = b_proj.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((bsz, nh, hd, n), jnp.float32)

    def body(state, inp):
        xt, dtt, bt, ct = inp  # [B,NH,HD], [B,NH], [B,N], [B,N]
        decay = jnp.exp(dtt * a_neg)[..., None, None]  # [B,NH,1,1]
        inc = jnp.einsum("bhd,bn->bhdn", xt * dtt[..., None], bt)
        state = decay * state + inc
        y = jnp.einsum("bn,bhdn->bhd", ct, state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b_proj, 1, 0), jnp.moveaxis(c_proj, 1, 0))
    state, ys = jax.lax.scan(body, init_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


class MambaCache(NamedTuple):
    state: jax.Array  # [B, NH, HD, N]
    conv_x: jax.Array  # [B, d_conv-1, di]
    conv_B: jax.Array  # [B, d_conv-1, N]
    conv_C: jax.Array  # [B, d_conv-1, N]


def mamba2_block(
    params: Dict, cfg, x: jax.Array, cache: MambaCache = None,
    return_state: bool = False,
):
    """Full Mamba2 block. x [B,S,d]. With ``cache`` set, S must be 1 (decode).

    ``return_state=True`` (prefill) additionally returns the post-sequence
    MambaCache so decoding can continue from the prompt.
    Returns (out, new_cache_or_None).
    """
    ssm = cfg.ssm
    bsz, s, d_model = x.shape
    di, nh, n = dims(d_model, ssm)
    z = shard(x @ params["w_z"], BATCH, None, MODEL)
    xc_raw = shard(x @ params["w_x"], BATCH, None, MODEL)
    b_raw = x @ params["w_B"]
    c_raw = x @ params["w_C"]
    dt_raw = x @ params["w_dt"]

    if cache is None:
        tail = (lambda a: a[:, -(ssm.d_conv - 1):]) if return_state else (lambda a: None)
        new_conv = (tail(xc_raw), tail(b_raw), tail(c_raw))
        xc = jax.nn.silu(_causal_conv(xc_raw, params["conv_x"], params["conv_bias"]))
        b = jax.nn.silu(_causal_conv(b_raw, params["conv_B"]))
        c = jax.nn.silu(_causal_conv(c_raw, params["conv_C"]))
    else:
        xc, nbx = _conv_step(cache.conv_x, xc_raw, params["conv_x"], params["conv_bias"])
        b, nbb = _conv_step(cache.conv_B, b_raw, params["conv_B"])
        c, nbc = _conv_step(cache.conv_C, c_raw, params["conv_C"])
        xc, b, c = jax.nn.silu(xc), jax.nn.silu(b), jax.nn.silu(c)
        new_conv = (nbx, nbb, nbc)

    xh = xc.reshape(bsz, s, nh, ssm.head_dim)
    xh = shard(xh, BATCH, None, MODEL, None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = shard(dt, BATCH, None, MODEL)
    a_neg = -jnp.exp(params["A_log"])

    if cache is None:
        y, new_state = ssd_chunked(xh, dt, a_neg, b, c, ssm.chunk)
    else:
        y, new_state = ssd_sequential(xh, dt, a_neg, b, c, cache.state)

    y = y + params["D"][:, None].astype(y.dtype) * xh  # skip
    y = y.reshape(bsz, s, di)
    y = rmsnorm(params["norm_g"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    out = shard(out, BATCH, None, None)
    if cache is None and not return_state:
        return out, None
    return out, MambaCache(new_state, *new_conv)


def init_mamba_cache(cfg, batch: int, dtype) -> MambaCache:
    ssm = cfg.ssm
    di, nh, n = dims(cfg.d_model, ssm)
    k = ssm.d_conv - 1
    return MambaCache(
        state=jnp.zeros((batch, nh, ssm.head_dim, n), jnp.float32),
        conv_x=jnp.zeros((batch, k, di), dtype),
        conv_B=jnp.zeros((batch, k, n), dtype),
        conv_C=jnp.zeros((batch, k, n), dtype),
    )
