"""GQA attention for the LM substrate.

Three execution paths, one semantics (oracle: kernels/ref.py):

* ``plain``   — materialized scores; smoke tests / small seq.
* ``chunked`` — pure-JAX FlashAttention-2: outer ``lax.scan`` over q chunks,
  inner scan over kv chunks, online softmax, **custom_vjp** backward that
  recomputes score tiles (saves only O and the row logsumexp L). This is the
  path the multi-pod dry-run lowers: it is memory-safe at 32k prefill / 4k
  train and its HLO is what the roofline analysis reads.
* Pallas ``flash_attention`` kernel — real-TPU hot path (cfg.use_pallas).

Layouts: q [B, S, H, Dh]; k/v [B, S, KVH, Dh]. Internally [B, KVH, G, S, Dh]
so GQA is explicit and the MXU sees 128-aligned matmuls.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import BATCH, MODEL, shard
from repro.kernels import ref as kref
from repro.nn.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash attention (pure JAX, custom_vjp)
# ---------------------------------------------------------------------------


def _chunk_for(s: int, target: int) -> int:
    """Largest chunk <= target that divides s (vision/audio prefixes make
    sequence lengths like 4352 = 4096 + 256)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _mask(rows, cols, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), bool)
    if causal:
        m &= rows >= cols
    if window:
        m &= rows - cols < window
    return m


def _fwd_scan(q5, k4, v4, causal, window, cq, ck, scale):
    """q5 [B,KVH,G,Sq,Dh]; k4/v4 [B,KVH,Sk,Dh] -> (out5, L [B,KVH,G,Sq])."""
    b, kvh, g, sq, dh = q5.shape
    sk = k4.shape[2]
    nq, nk = sq // cq, sk // ck
    qch = jnp.moveaxis(q5.reshape(b, kvh, g, nq, cq, dh), 3, 0)  # [nq,...]
    kch = jnp.moveaxis(k4.reshape(b, kvh, nk, ck, dh), 2, 0)  # [nk,...]
    vch = jnp.moveaxis(v4.reshape(b, kvh, nk, ck, dh), 2, 0)

    def one_q(qi, qc):
        rows = qi * cq + jnp.arange(cq)

        def kv_body(carry, inp):
            m, l, acc = carry
            ki, kc, vc = inp
            cols = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(rows[:, None], cols[None, :], causal, window)
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, cq), jnp.float32),
            jnp.zeros((b, kvh, g, cq, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_body, init, (jnp.arange(nk), kch, vch))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(q5.dtype)
        return out, m + jnp.log(l_safe)

    _, (out_ch, l_ch) = jax.lax.scan(
        lambda c, x: (c, one_q(x[0], x[1])), 0, (jnp.arange(nq), qch))
    out = jnp.moveaxis(out_ch, 0, 3).reshape(b, kvh, g, sq, dh)
    lse = jnp.moveaxis(l_ch, 0, 3).reshape(b, kvh, g, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _chunked5(q5, k4, v4, causal, window, cq, ck):
    scale = 1.0 / np.sqrt(q5.shape[-1])
    out, _ = _fwd_scan(q5, k4, v4, causal, window, cq, ck, scale)
    return out


def _chunked5_fwd(q5, k4, v4, causal, window, cq, ck):
    scale = 1.0 / np.sqrt(q5.shape[-1])
    out, lse = _fwd_scan(q5, k4, v4, causal, window, cq, ck, scale)
    return out, (q5, k4, v4, out, lse)


def _chunked5_bwd(causal, window, cq, ck, res, dout):
    q5, k4, v4, out, lse = res
    scale = 1.0 / np.sqrt(q5.shape[-1])
    b, kvh, g, sq, dh = q5.shape
    sk = k4.shape[2]
    nq, nk = sq // cq, sk // ck
    dout = dout.astype(jnp.float32)
    delta = (dout * out.astype(jnp.float32)).sum(-1)  # [B,KVH,G,Sq]

    qch = jnp.moveaxis(q5.reshape(b, kvh, g, nq, cq, dh), 3, 0)
    doch = jnp.moveaxis(dout.reshape(b, kvh, g, nq, cq, dh), 3, 0)
    lch = jnp.moveaxis(lse.reshape(b, kvh, g, nq, cq), 3, 0)
    dch = jnp.moveaxis(delta.reshape(b, kvh, g, nq, cq), 3, 0)
    kch = jnp.moveaxis(k4.reshape(b, kvh, nk, ck, dh), 2, 0)
    vch = jnp.moveaxis(v4.reshape(b, kvh, nk, ck, dh), 2, 0)

    def p_tile(qc, kc, rows, cols, lse_c):
        s = jnp.einsum("bkgqd,bktd->bkgqt", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(rows[:, None], cols[None, :], causal, window)
        p = jnp.exp(jnp.where(msk, s, NEG_INF) - lse_c[..., None])
        return jnp.where(msk, p, 0.0)

    # pass 1: dQ — for each q chunk, scan kv chunks
    def dq_chunk(carry, x):
        qi, qc, do, lse_c, d_c = x
        rows = qi * cq + jnp.arange(cq)

        def body(dq, inp):
            ki, kc, vc = inp
            cols = ki * ck + jnp.arange(ck)
            p = p_tile(qc, kc, rows, cols, lse_c)
            dp = jnp.einsum("bkgqd,bktd->bkgqt", do, vc.astype(jnp.float32))
            ds = p * (dp - d_c[..., None])
            return dq + scale * jnp.einsum(
                "bkgqt,bktd->bkgqd", ds, kc.astype(jnp.float32)), None

        dq0 = jnp.zeros((b, kvh, g, cq, dh), jnp.float32)
        dq, _ = jax.lax.scan(body, dq0, (jnp.arange(nk), kch, vch))
        return carry, dq

    _, dqch = jax.lax.scan(dq_chunk, 0, (jnp.arange(nq), qch, doch, lch, dch))
    dq = jnp.moveaxis(dqch, 0, 3).reshape(b, kvh, g, sq, dh).astype(q5.dtype)

    # pass 2: dK, dV — for each kv chunk, scan q chunks
    def dkv_chunk(carry, x):
        ki, kc, vc = x
        cols = ki * ck + jnp.arange(ck)

        def body(carry, inp):
            dk, dv = carry
            qi, qc, do, lse_c, d_c = inp
            rows = qi * cq + jnp.arange(cq)
            p = p_tile(qc, kc, rows, cols, lse_c)
            dv = dv + jnp.einsum("bkgqt,bkgqd->bktd", p, do)
            dp = jnp.einsum("bkgqd,bktd->bkgqt", do, vc.astype(jnp.float32))
            ds = p * (dp - d_c[..., None])
            dk = dk + scale * jnp.einsum(
                "bkgqt,bkgqd->bktd", ds, qc.astype(jnp.float32))
            return (dk, dv), None

        init = (jnp.zeros((b, kvh, ck, dh), jnp.float32),
                jnp.zeros((b, kvh, ck, dh), jnp.float32))
        (dk, dv), _ = jax.lax.scan(
            body, init, (jnp.arange(nq), qch, doch, lch, dch))
        return carry, (dk, dv)

    _, (dkch, dvch) = jax.lax.scan(dkv_chunk, 0, (jnp.arange(nk), kch, vch))
    dk = jnp.moveaxis(dkch, 0, 2).reshape(b, kvh, sk, dh).astype(k4.dtype)
    dv = jnp.moveaxis(dvch, 0, 2).reshape(b, kvh, sk, dh).astype(v4.dtype)
    return dq, dk, dv


_chunked5.defvjp(_chunked5_fwd, _chunked5_bwd)


def chunked_attention(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, KVH, Dh]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    chunk_q: int = 1024,
    chunk_k: int = 1024,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    sk = k.shape[1]
    cq, ck = min(chunk_q, sq), min(chunk_k, sk)
    assert sq % cq == 0 and sk % ck == 0, (sq, cq, sk, ck)
    q5 = q.transpose(0, 2, 1, 3).reshape(b, kvh, h // kvh, sq, dh)
    k4 = k.transpose(0, 2, 1, 3)
    v4 = v.transpose(0, 2, 1, 3)
    o5 = _chunked5(q5, k4, v4, causal, window, cq, ck)
    return o5.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# attention block (projections + rope + path select + KV cache decode)
# ---------------------------------------------------------------------------


def init_attention(rng: jax.Array, cfg, d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    dh = cfg.resolved_head_dim
    h0, kvh0 = cfg.n_heads, cfg.n_kv_heads
    h, kvh = _heads(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    pd = jnp.dtype(cfg.param_dtype)
    s = 1.0 / np.sqrt(d)

    def col_padded(key, cols0, cols):
        # padded head slices are ZERO-initialized: the padded model computes
        # exactly the assigned architecture at init (padded heads emit zero
        # attention output); they become extra trainable capacity afterwards.
        w = jax.random.normal(key, (d, cols0)) * s
        if cols > cols0:
            w = jnp.concatenate([w, jnp.zeros((d, cols - cols0))], axis=1)
        return w.astype(pd)

    wo = jax.random.normal(k4, (h0 * dh, d)) * s / np.sqrt(2 * cfg.n_layers)
    if h > h0:
        wo = jnp.concatenate([wo, jnp.zeros(((h - h0) * dh, d))], axis=0)
    return {
        "wq": col_padded(k1, h0 * dh, h * dh),
        "wk": col_padded(k2, kvh0 * dh, kvh * dh),
        "wv": col_padded(k3, kvh0 * dh, kvh * dh),
        "wo": wo.astype(pd),
    }


def _padded_heads(cfg) -> Tuple[int, int]:
    """Heads padded up to a multiple of 16 (the 'model' axis) — beyond-paper
    optimization for archs like arctic (56 q heads, 8 kv heads)."""
    pad = lambda n: int(-(-n // 16) * 16)
    return pad(cfg.n_heads), pad(cfg.n_kv_heads)


def _heads(cfg) -> Tuple[int, int]:
    return _padded_heads(cfg) if cfg.pad_heads_to_mesh else (cfg.n_heads, cfg.n_kv_heads)


def qkv(params: Dict, cfg, x: jax.Array, positions: jax.Array, use_rope: bool = True):
    """Project + rope. x [B,S,d] -> q [B,S,H,Dh], k/v [B,S,KVH,Dh]."""
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    h, kvh = _heads(cfg)
    q = shard((x @ params["wq"]).reshape(b, s, h, dh), BATCH, None, MODEL, None)
    k = shard((x @ params["wk"]).reshape(b, s, kvh, dh), BATCH, None, MODEL, None)
    v = shard((x @ params["wv"]).reshape(b, s, kvh, dh), BATCH, None, MODEL, None)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    params: Dict,
    cfg,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,  # [S] or [B, S]
    causal: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    return_kv: bool = False,
    use_rope: bool = True,
):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, d = x.shape
    q, k, v = qkv(params, cfg, x, positions, use_rope=use_rope)
    if kv_override is not None:  # cross-attention: kv from encoder
        k, v = kv_override
    window = cfg.sliding_window
    if cfg.use_pallas and jax.default_backend() == "tpu":
        from repro.kernels.flash_attention import flash_attention

        o = flash_attention(q, k, v, causal=causal, window=window)
    elif s <= 1024 and k.shape[1] <= 1024:
        o = kref.mha_attention(q, k, v, causal=causal, window=window) \
            if k.shape[1] == s else _plain_cross(q, k, v)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              chunk_q=_chunk_for(s, cfg.attn_chunk),
                              chunk_k=_chunk_for(k.shape[1], cfg.attn_chunk))
    o = shard(o, BATCH, None, MODEL, None)
    out = o.reshape(b, s, -1) @ params["wo"]
    # sequence-parallel epilogue (Megatron SP): scatter the seq dim back
    out = shard(out, BATCH, MODEL if cfg.seq_shard_activations else None, None)
    if return_kv:
        return out, (k, v)
    return out


def _plain_cross(q, k, v):
    """Non-causal cross attention with mismatched lengths (small seq)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    p = jax.nn.softmax(s / np.sqrt(dh), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", p, v).reshape(b, sq, h, dh)


# ---------------- decode (single token, KV cache) ----------------


def decode_attention_block(
    params: Dict,
    cfg,
    x: jax.Array,  # [B, 1, d]
    cache_k: jax.Array,  # [B, S, KVH, Dh]
    cache_v: jax.Array,
    pos: jax.Array,  # [] int32 current position (same across batch)
):
    """One decode step: update cache at ``pos``, attend over the cache.

    The cache's sequence dim is sharded over 'model' (flash-decode layout,
    cfg.decode_kv_shard_seq); XLA turns the masked softmax into partial
    max/sum + all-reduce across the model axis.
    """
    b, _, d = x.shape
    dh = cfg.resolved_head_dim
    h, kvh = _heads(cfg)
    smax = cache_k.shape[1]
    q = (x @ params["wq"]).reshape(b, 1, h, dh)
    k_new = (x @ params["wk"]).reshape(b, 1, kvh, dh)
    v_new = (x @ params["wv"]).reshape(b, 1, kvh, dh)
    pos_b = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos_b[:, None], cfg.rope_theta)
    # ring-buffer write for sliding window, plain write otherwise
    widx = (pos % smax) if cfg.sliding_window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), widx, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), widx, axis=1)
    seq_spec = MODEL if cfg.decode_kv_shard_seq else None
    kvh_spec = None if cfg.decode_kv_shard_seq else MODEL
    cache_k = shard(cache_k, BATCH, seq_spec, kvh_spec, None)
    cache_v = shard(cache_v, BATCH, seq_spec, kvh_spec, None)
    kv_len = jnp.minimum(pos + 1, smax)
    if cfg.use_pallas and jax.default_backend() == "tpu":
        from repro.kernels.decode_attention import decode_attention as pl_dec

        o = pl_dec(q[:, 0], cache_k, cache_v, kv_len)
    else:
        o = kref.decode_attention(q[:, 0], cache_k, cache_v, kv_len)
    o = shard(o, BATCH, MODEL, None)
    out = o.reshape(b, 1, -1) @ params["wo"]
    return shard(out, BATCH, None, None), cache_k, cache_v
