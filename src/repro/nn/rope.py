"""Rotary position embeddings with arbitrary position offsets (decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)
