"""RMSNorm (computed in fp32, cast back)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(g: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)
