"""Top-k routed Mixture-of-Experts (GShard-style capacity dispatch).

Token-choice top-k routing with a static per-expert capacity
``C = ceil(T/E * k * capacity_factor)``; overflow tokens drop to the dense
residual (arctic) or to the residual stream.  Dispatch/combine are expressed
as scatter-add / gather so the compiled HLO shows the paper's TB-Type
(topology = routing table) + DR-Type (permute) classes explicitly — the MoE
analogue of neighbor aggregation, which is exactly where the characterizer
places it (DESIGN.md §4).

Sharding: expert dim over 'model' (EP); token dim over ('pod','data').
XLA inserts the dispatch all-to-all at the scatter boundary.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.dist.sharding import BATCH, MODEL, shard
from repro.nn.mlp import init_mlp, mlp_block


def init_moe(rng: jax.Array, d: int, cfg_moe: MoEConfig, n_layers: int,
             param_dtype) -> Dict:
    e, ff = cfg_moe.n_experts, cfg_moe.d_ff_expert
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    pd = jnp.dtype(param_dtype)
    s = 1.0 / np.sqrt(d)
    params = {
        "router": (jax.random.normal(k1, (d, e)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, ff)) * s).astype(pd),
        "w_up": (jax.random.normal(k3, (e, d, ff)) * s).astype(pd),
        "w_down": (jax.random.normal(k4, (e, ff, d)) * s / np.sqrt(2 * n_layers)).astype(pd),
    }
    if cfg_moe.dense_residual_ff:
        params["dense"] = init_mlp(k5, d, cfg_moe.dense_residual_ff, n_layers, param_dtype)
    return params


def _capacity(t: int, cfg_moe: MoEConfig) -> int:
    c = int(np.ceil(t * cfg_moe.top_k / cfg_moe.n_experts * cfg_moe.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_block(params: Dict, x: jax.Array, cfg_moe: MoEConfig,
              n_groups: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar).

    GROUP-LOCAL dispatch (GShard local groups): tokens are split into
    ``n_groups`` groups aligned with the batch dim; routing positions are
    cumsum'd within each group, so the dispatch scatter never crosses data
    shards.  Measured on phi3.5-moe train_4k (EXPERIMENTS.md §Perf H-B1):
    global cumsum forces GSPMD to all-reduce the full [E,C,d] buffer every
    layer (963 GiB/step/device); group-local turns it into the single
    dispatch all-to-all.  Default n_groups = batch size (every sequence its
    own group).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg_moe.n_experts, cfg_moe.top_k
    g_n = n_groups or b
    tg = t // g_n  # tokens per group
    cap = _capacity(tg, cfg_moe)
    xt = x.reshape(g_n, tg, d)
    xt = shard(xt, BATCH, None, None)

    # ---- router (fp32) ----
    logits = xt.astype(jnp.float32) @ params["router"]  # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)  # [G, Tg, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- group-local position-in-expert (cumsum within each group) ----
    e_flat = gate_idx.reshape(g_n, tg * k)  # expert id per choice
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # [G, Tg*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(pos, e_flat[..., None], axis=2)[..., 0]
    keep = pos_in_e < cap  # [G, Tg*k]
    w_flat = gate_w.reshape(g_n, tg * k) * keep.astype(jnp.float32)

    # ---- dispatch: per-group scatter into [G, E, C, d] (TB-Type) ----
    tok_idx = jnp.repeat(jnp.arange(tg), k)  # [Tg*k] (same for every group)
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)
    gather = jnp.take(xt, tok_idx, axis=1)  # [G, Tg*k, d]
    gather = gather * keep[..., None].astype(x.dtype)
    xe = jnp.zeros((g_n, e, cap, d), x.dtype)
    gid = jnp.broadcast_to(jnp.arange(g_n)[:, None], e_flat.shape)
    xe = xe.at[gid, e_flat, safe_pos].add(gather, mode="drop")
    # Sharding choice (measured, §Perf): experts over 'model', groups
    # unsharded in the buffer. H-B3 (groups@data too) makes GSPMD replicate
    # the scatter (coll 31->210s); H-B5 (groups@data only, experts via the
    # einsum weights) trades the all-reduce for a larger collective-permute
    # (34.7s vs 31.1s). H-B1 (this form) won on both cells.
    xe = shard(xe, None, MODEL, None, None)

    # ---- expert FFN (DM-Type, batched over experts) ----
    gact = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", gact * u, params["w_down"])
    ye = shard(ye, None, MODEL, None, None)

    # ---- combine: gather back + weighted sum over the k choices ----
    yt = ye[gid, e_flat, safe_pos] * w_flat[..., None].astype(x.dtype)
    tok2 = jnp.broadcast_to(tok_idx[None, :], (g_n, tg * k))
    out = jnp.zeros((g_n, tg, d), x.dtype).at[gid, tok2].add(yt, mode="drop")
    out = out.reshape(b, s, d)
    out = shard(out, BATCH, None, None)

    if "dense" in params:  # arctic: parallel dense residual FFN
        out = out + mlp_block(params["dense"], x)
    return out, aux
