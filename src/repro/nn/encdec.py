"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder consumes precomputed audio frame embeddings (the modality frontend is
a stub per the brief — ``input_specs`` provides [B, S_src, d] frames).
Decoder: causal self-attention (+KV cache) + cross-attention to the encoder
output (cross-KV precomputed once at prefill, rope-free) + SwiGLU MLP.
Both sides scan over stacked layers like transformer.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import BATCH, MODEL, shard
from repro.kernels import ref as kref
from repro.nn import attention as attn
from repro.nn.mlp import init_mlp, mlp_block
from repro.nn.norm import init_rmsnorm, rmsnorm
from repro.nn.transformer import _remat, chunked_ce


def _enc_block_init(rng, cfg):
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return {
        "ln1": init_rmsnorm(d),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_rmsnorm(d),
        "mlp": init_mlp(k2, d, cfg.d_ff, cfg.n_layers, cfg.param_dtype),
    }


def _dec_block_init(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.d_model
    return {
        "ln1": init_rmsnorm(d),
        "self_attn": attn.init_attention(k1, cfg),
        "ln2": init_rmsnorm(d),
        "cross_attn": attn.init_attention(k2, cfg),
        "ln3": init_rmsnorm(d),
        "mlp": init_mlp(k3, d, cfg.d_ff, cfg.n_layers, cfg.param_dtype),
    }


def init_encdec_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    keys = jax.random.split(rng, 6)
    d, v = cfg.d_model, cfg.vocab
    pd = jnp.dtype(cfg.param_dtype)
    enc = [_enc_block_init(jax.random.fold_in(keys[0], j), cfg)
           for j in range(cfg.enc_layers)]
    dec = [_dec_block_init(jax.random.fold_in(keys[1], j), cfg)
           for j in range(cfg.dec_layers)]
    return {
        "embed": (jax.random.normal(keys[2], (v, d)) * 0.02).astype(pd),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "ln_enc": init_rmsnorm(d),
        "ln_f": init_rmsnorm(d),
        "lm_head": (jax.random.normal(keys[3], (d, v)) / np.sqrt(d)).astype(pd),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames [B, Ss, d] -> encoder hidden [B, Ss, d] (bidirectional)."""
    x = shard(frames.astype(jnp.dtype(cfg.dtype)), BATCH, None, None)
    positions = jnp.arange(x.shape[1])

    def body(xc, p):
        h = attn.attention_block(p["attn"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps),
                                 positions, causal=False)
        xc = xc + h
        xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln2"], xc, cfg.norm_eps))
        return xc, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc"])
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def _cross_kv(p, cfg, enc_out):
    b, ss, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    _, kvh = attn._heads(cfg)
    k = shard((enc_out @ p["wk"]).reshape(b, ss, kvh, dh), BATCH, None, MODEL, None)
    v = shard((enc_out @ p["wv"]).reshape(b, ss, kvh, dh), BATCH, None, MODEL, None)
    return k, v


def decode_train(params, cfg: ModelConfig, tokens: jax.Array, enc_out: jax.Array):
    """Teacher-forced decoder pass -> hidden [B, St, d]."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard(x, BATCH, None, None)
    positions = jnp.arange(x.shape[1])

    def body(xc, p):
        h = attn.attention_block(p["self_attn"], cfg,
                                 rmsnorm(p["ln1"], xc, cfg.norm_eps), positions, causal=True)
        xc = xc + h
        kv = _cross_kv(p["cross_attn"], cfg, enc_out)
        h = attn.attention_block(p["cross_attn"], cfg,
                                 rmsnorm(p["ln2"], xc, cfg.norm_eps), positions,
                                 causal=False, kv_override=kv, use_rope=False)
        xc = xc + h
        xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln3"], xc, cfg.norm_eps))
        return xc, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec"])
    return x


def encdec_forward(params, cfg: ModelConfig, frames, tokens):
    enc_out = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, enc_out)
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h @ params["lm_head"]


def encdec_loss(params, cfg: ModelConfig, batch: Dict):
    h = decode_train(params, cfg, batch["tokens"],
                     encode(params, cfg, batch["frames"]))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    chunk_tokens = max(8, int(2 ** 24 / max(cfg.vocab, 1)))
    return chunked_ce(h, params["lm_head"], jnp.maximum(labels, 0), mask, chunk_tokens)


# ---------------- serving ----------------


def encdec_prefill(params, cfg: ModelConfig, frames, tokens):
    """Encode + teacher-forced prefix -> (last logits, caches).

    caches = {"self": {k,v stacked [L,...]}, "cross": {k,v [L,...]}}.
    """
    enc_out = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])

    def body(xc, p):
        h, kv_self = attn.attention_block(
            p["self_attn"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps), positions,
            causal=True, return_kv=True)
        xc = xc + h
        kv_cross = _cross_kv(p["cross_attn"], cfg, enc_out)
        h = attn.attention_block(p["cross_attn"], cfg,
                                 rmsnorm(p["ln2"], xc, cfg.norm_eps), positions,
                                 causal=False, kv_override=kv_cross, use_rope=False)
        xc = xc + h
        xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln3"], xc, cfg.norm_eps))
        return xc, {"self_k": kv_self[0], "self_v": kv_self[1],
                    "cross_k": kv_cross[0], "cross_v": kv_cross[1]}

    x, caches = jax.lax.scan(body, x, params["dec"])
    h = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return h @ params["lm_head"], caches


def init_encdec_caches(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    dh = cfg.resolved_head_dim
    _, kvh = attn._heads(cfg)
    dt = jnp.dtype(cfg.dtype)
    L = cfg.dec_layers
    return {
        "self_k": jnp.zeros((L, batch, max_len, kvh, dh), dt),
        "self_v": jnp.zeros((L, batch, max_len, kvh, dh), dt),
        "cross_k": jnp.zeros((L, batch, src_len, kvh, dh), dt),
        "cross_v": jnp.zeros((L, batch, src_len, kvh, dh), dt),
    }


def encdec_decode_step(params, cfg: ModelConfig, token, caches, pos):
    """token [B,1]; caches dict of stacked [L,...]; pos [] int32."""
    x = jnp.take(params["embed"], token, axis=0).astype(jnp.dtype(cfg.dtype))
    src_len = caches["cross_k"].shape[2]

    def body(xc, xs):
        p, ck, cv, xk, xv = xs
        h, nk, nv = attn.decode_attention_block(
            p["self_attn"], cfg, rmsnorm(p["ln1"], xc, cfg.norm_eps), ck, cv, pos)
        xc = xc + h
        # cross attention: rope-free q over static cross KV
        b = xc.shape[0]
        dh = cfg.resolved_head_dim
        h_, _ = attn._heads(cfg)
        q = (rmsnorm(p["ln2"], xc, cfg.norm_eps) @ p["cross_attn"]["wq"]).reshape(b, h_, dh)
        o = kref.decode_attention(q, xk, xv, src_len)
        xc = xc + o.reshape(b, 1, -1) @ p["cross_attn"]["wo"]
        xc = xc + mlp_block(p["mlp"], rmsnorm(p["ln3"], xc, cfg.norm_eps))
        return xc, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], caches["self_k"], caches["self_v"],
                  caches["cross_k"], caches["cross_v"]))
    caches = dict(caches, self_k=nk, self_v=nv)
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return h @ params["lm_head"], caches
