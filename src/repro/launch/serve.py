"""Serving launchers: LM batched generation + stage-aware sharded HGNN inference.

LM slot engine:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 8 --max-tokens 16

HGNN inference (the paper's workloads, partitioned by stage taxonomy):

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.serve --hgnn han --dataset imdb \
      --mesh-data 2 --mesh-model 4
"""
from __future__ import annotations

import argparse
import time
from typing import Any, NamedTuple, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.configs import get_config, get_reduced
from repro.configs.base import HGNNConfig
from repro.dist.sharding import resolve_spec, use_mesh
from repro.nn.transformer import init_lm_params
from repro.serve.engine import Request, ServeEngine


# ---------------------------------------------------------------------------
# stage-aware sharded HGNN inference
# ---------------------------------------------------------------------------


class BuiltHGNNInfer(NamedTuple):
    fn: Any      # jitted (params, batch) -> logits
    params: Any  # device_put with stage-aware shardings (if mesh given)
    batch: Any
    plan: Any = None      # the StagePlan the executor runs
    executor: Any = None  # StageGraphExecutor (characterization hooks)


def hgnn_shardings(plan, params: Any, batch: Any, mesh: Mesh):
    """Resolve a plan's declarative sharding tables into NamedShardings.

    ``plan.param_specs`` / ``plan.batch_specs`` are (key, ndim, logical-spec)
    rules (see ``repro.core.plan``): a pytree leaf whose dict path contains
    ``key`` and whose rank matches gets the resolved spec; everything else
    (attention vectors, classifier, feature pools) replicates.  The rules
    follow ``HGNN_STAGE_SPECS`` — FP weights column-sharded over 'model',
    destination-node tables over the BATCH axes, source pools replicated —
    and cover every layout (stacked, bucketed, per-relation, instance)
    without model-specific branches here.
    """
    rep = NamedSharding(mesh, P())

    def named(shape, logical):
        return NamedSharding(mesh, resolve_spec(shape, logical, mesh))

    def resolver(rules):
        def fn(path, leaf):
            keys = [k.key for k in path if isinstance(k, DictKey)]
            nd = getattr(leaf, "ndim", None)
            for key, ndim, spec in rules:
                if nd == ndim and key in keys:
                    return named(leaf.shape, spec)
            return rep
        return fn

    return (tree_map_with_path(resolver(plan.param_specs), params),
            tree_map_with_path(resolver(plan.batch_specs), batch))


def build_hgnn_infer(cfg: HGNNConfig, hg, mesh: Optional[Mesh] = None,
                     rng: Optional[jax.Array] = None) -> BuiltHGNNInfer:
    """Stage-aware sharded HGNN inference entry point — plan-driven.

    The paper's finding — FP is dense DM-Type, NA is irregular TB-Type, SA is
    EW-Type — becomes the partitioning strategy: FP shards its projection
    matmul over 'model', padded NA shards destination nodes over the batch
    axes with a replicated source pool, SA needs no resharding.  With
    ``mesh=None`` this is the plain single-device path (identical math).
    A padded NA layout is required on a mesh (``cfg.fused=True`` for
    HAN/RGCN; MAGNN's instance tables always shard).
    """
    from repro.core.models import get_model

    model = get_model(cfg)
    plan = model.plan()
    if cfg.fuse_na_sa and not plan.sa.fuse_epilogue:
        import warnings

        warnings.warn(
            f"fuse_na_sa requested but {plan.model}'s NA layout "
            f"({plan.na.layout!r}) does not support the NA→SA epilogue "
            "(stacked only); running two-pass SA", stacklevel=2)
    if mesh is not None and not plan.shards_on_mesh:
        raise ValueError(
            f"sharded HGNN inference needs a padded NA layout, but "
            f"{plan.model}'s plan resolved to 'csr' (gather/scatter cannot "
            "shard): set cfg.fused=True for HAN/RGCN; GCN has no sharded "
            "layout")
    batch = model.prepare(hg)
    params = model.init(rng if rng is not None else jax.random.key(cfg.seed),
                        batch)

    if mesh is None:
        # an async stage-graph schedule swaps the jitted monolith for the
        # overlapped dispatcher (bit-exact; per-stage jits cached on the
        # executor).  Sampled serving keeps the monolith — there the
        # schedule's overlap source is the engine's sampler prefetch
        # thread, and the serve engine diffs the jit cache for its
        # compiles_after_warmup guarantee.
        if plan.schedule is not None and plan.sample is None:
            return BuiltHGNNInfer(model.executor.forward_overlapped, params,
                                  batch, plan, model.executor)
        return BuiltHGNNInfer(jax.jit(model.forward), params, batch,
                              plan, model.executor)

    def fn(p, b):
        with use_mesh(mesh):
            return model.forward(p, b)

    p_sh, b_sh = hgnn_shardings(plan, params, batch, mesh)
    params = jax.device_put(params, p_sh)
    batch = jax.device_put(batch, b_sh)
    return BuiltHGNNInfer(jax.jit(fn), params, batch, plan, model.executor)


def build_fault_injector(args, part) -> Any:
    """``--inject-faults SEED`` -> the chaos-smoke schedule: two transient
    sampler faults + one transient forward fault (absorbed by retries), one
    persistent sampler fault (fails the step's requests), injected latency
    on three steps (drives the degradation ladder when --slo-ms is set),
    and — partitioned runs only — one partition loss at step 3 (failover
    re-partitions over the survivors).  Deterministic per seed."""
    from repro.serve.faults import FaultInjector

    return FaultInjector.seeded(
        seed=args.inject_faults, n_steps=max(args.requests, 8),
        sampler=2, forward=1, persistent_sampler=1, latency_steps=3,
        latency_s=(args.slo_ms or 50.0) / 250.0,
        partition_loss_step=3 if part is not None and part.k > 1 else None,
        partition=0)


def run_hgnn_serve(args, cfg: HGNNConfig, hg, built: BuiltHGNNInfer) -> None:
    """Request-path serving: neighbor-sampled minibatches through the
    slot-based continuous-batching engine (``--fanout >= 1``)."""
    from repro.serve.engine import HGNNRequest, HGNNServeEngine
    from repro.serve.resilience import ResilienceConfig
    from repro.serve.sampler import HGNNSampler

    sampler = HGNNSampler(built.plan, cfg, hg)
    part = built.plan.partition
    res = ResilienceConfig(max_queue=args.max_queue,
                           deadline_ms=args.deadline_ms,
                           slo_ms=args.slo_ms,
                           slo_signal=args.slo_signal)
    injector = (build_fault_injector(args, part)
                if args.inject_faults is not None else None)
    engine = HGNNServeEngine(built.executor, built.params, sampler,
                             slots=args.slots,
                             slot_targets=args.slot_targets, fn=built.fn,
                             resilience_cfg=res, injector=injector)
    n_t = hg.node_counts[built.plan.target]
    rng = np.random.default_rng(0)
    reqs = [
        HGNNRequest(targets=rng.integers(
            0, n_t, size=int(rng.integers(1, 2 * args.slot_targets + 1))))
        for _ in range(args.requests)
    ]
    n_targets = sum(len(r.targets) for r in reqs)
    t0 = time.time()
    engine.warmup()
    warm = time.time() - t0
    t0 = time.time()
    engine.serve(reqs)
    dt = time.time() - t0
    st = engine.stats()
    rungs = ";".join(f"{i}:{n}" for i, n in st["rung_hits"].items())
    print(f"serve {cfg.model}/{cfg.dataset}"
          f"{f' +partitions={part.k}' if part is not None else ''} "
          f"requests={len(reqs)} targets={n_targets} slots={args.slots} "
          f"slot_targets={args.slot_targets} fanout={cfg.fanout} "
          f"steps={st['steps']} recompiles={st['compiles_after_warmup']} "
          f"frontier_bytes={st['frontier_bytes']:.0f} "
          f"truncated={st['truncated_rows']} rung_hits={rungs} "
          f"warmup_ms={warm*1e3:.2f} wall_ms={dt*1e3:.2f} "
          f"step_ms={st['wall_mean_ms']:.3f}")
    rs = st["resilience"]
    print(f"  resilience: ok={rs['ok_requests']} "
          f"partial={rs['partial_requests']} failed={rs['failed_requests']} "
          f"rejected={rs['rejected']} shed={rs['shed']} "
          f"retries={rs['retries']} failed_steps={rs['failed_steps']} "
          f"deadline_expired={rs['deadline_expired']} "
          f"degrade_steps={rs['degrade_steps']} "
          f"max_degrade_level={rs['max_degrade_level']} "
          f"failovers={rs['partition_failovers']}")
    if "prefetch" in st:
        pf = st["prefetch"]
        print(f"  prefetch: issued={pf['issued']} hits={pf['hits']} "
              f"mispredicts={pf['mispredicts']} cold={pf['cold']}")
    if "residency" in st:
        rd = st["residency"]
        print(f"  residency: cache_rows={rd['cache_rows']} "
              f"hits={rd['hits']} misses={rd['misses']} rows={rd['rows']} "
              f"hit_rate={rd['hit_rate']:.3f} evictions={rd['evictions']}")
    if args.characterize:
        sb = engine.last_sb
        recs = built.executor.stage_records(built.params, sb.batch,
                                            sample_meta=sb.meta)
        sm = recs["stages"]["SAMPLE"]
        print(f"  SAMPLE: rung={sm['rung']} n_targets={sm['n_targets']} "
              f"frontier_rows={sm['frontier_rows']} "
              f"frontier_bytes={sm['frontier_bytes']:.3g} "
              f"index_bytes={sm['index_bytes']:.3g}")
        for stage, rec in recs["stages"].items():
            if stage == "SAMPLE":
                continue
            print(f"  {stage}: flops={rec['flops']:.3g} "
                  f"hbm_bytes={rec['hbm_bytes']:.3g} "
                  f"bound={rec['roofline']['bound']}")


def run_hgnn(args) -> None:
    from repro.data.synthetic import make_dataset
    from repro.launch.mesh import make_smoke_mesh
    from repro.serve.engine import HGNNInferEngine

    if args.hgnn == "gcn" and args.dataset != "reddit":
        raise SystemExit("--hgnn gcn runs the paper's homogeneous GNN "
                         "comparison: use --dataset reddit")
    cfg = HGNNConfig(model=args.hgnn, dataset=args.dataset, fused=True,
                     use_pallas=args.use_pallas,
                     degree_buckets=args.degree_buckets,
                     fuse_na_sa=args.fuse_na_sa,
                     partitions=args.partitions,
                     layers=args.layers,
                     fanout=args.fanout,
                     cache_rows=args.cache_rows,
                     overlap=args.overlap)
    hg = make_dataset(args.dataset)
    mesh = None
    if args.mesh_data * args.mesh_model > 1:
        if args.fanout >= 1:
            raise SystemExit("--fanout serving runs single-device or "
                             "graph-partitioned (--partitions); it does not "
                             "combine with a --mesh-data/--mesh-model mesh")
        mesh = make_smoke_mesh(data=args.mesh_data, model=args.mesh_model)
    built = build_hgnn_infer(cfg, hg, mesh)
    if args.fanout >= 1:
        run_hgnn_serve(args, cfg, hg, built)
        return
    engine = HGNNInferEngine(built.executor, built.params, built.batch,
                             fn=built.fn)
    logits = jax.block_until_ready(engine.infer())
    t0 = time.time()
    for _ in range(args.iters):
        logits = jax.block_until_ready(engine.infer())
    dt = (time.time() - t0) / max(args.iters, 1)
    mesh_desc = (f"{dict(zip(mesh.axis_names, mesh.devices.shape))}"
                 if mesh else "single-device")
    na = built.plan.na
    part = built.plan.partition
    n_l = built.plan.n_layers
    print(f"{cfg.model}/{cfg.dataset} [na={na.kind}/{na.layout}"
          f"{' +fused-sa' if built.plan.sa.fuse_epilogue else ''}"
          f"{f' +partitions={part.k}' if part is not None else ''}"
          f"{f' x{n_l}layers' if n_l > 1 else ''}"
          f"{f' +overlap={cfg.overlap}' if built.plan.schedule else ''}] "
          f"logits {logits.shape} on {mesh_desc}: {dt*1e3:.2f} ms/iter")
    if built.plan.schedule is not None and mesh is None:
        ov = built.executor.overlap_record()
        d = built.executor.last_dispatch
        print(f"  overlap: depth={ov['depth']} stages={ov['stages']} "
              f"edges={ov['edges']} "
              f"concurrent_pairs={ov['concurrent_pairs']} "
              f"overlapped_stages={ov['overlapped_stages']} "
              f"max_inflight={d.get('max_inflight', 1)}")
    res = (built.batch.get("residency")
           if isinstance(built.batch, dict) else None)
    if res is not None:
        ct = res["counters"]
        print(f"  residency: cache_rows={ct['cache_rows']} "
              f"hits={ct['hits']} misses={ct['misses']} rows={ct['rows']} "
              f"hit_rate={ct['hits'] / max(ct['rows'], 1):.3f}")
    if args.characterize:
        # one stage_records call covers both the per-stage table and the
        # partition summary (lower+compile+HLO walk per stage is expensive)
        recs = built.executor.stage_records(built.params, built.batch)
        for stage, rec in recs["stages"].items():
            extra = (f" halo_bytes={rec['halo_bytes']:.3g}"
                     if "halo_bytes" in rec else "")
            print(f"  {stage}: flops={rec['flops']:.3g} "
                  f"hbm_bytes={rec['hbm_bytes']:.3g} "
                  f"bound={rec['roofline']['bound']}{extra}")
        if "partition" in recs:
            pt = recs["partition"]
            print(f"  partition: k={pt['k']} cut_ratio={pt['cut_ratio']:.3f} "
                  f"halo_rows={pt['halo_rows']:.0f} "
                  f"halo_bytes={pt['halo_bytes']:.3g} "
                  f"(x{pt['layers']} layers = "
                  f"{pt['halo_bytes_total']:.3g} total)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4)
    # HGNN inference mode (stage-aware sharded; see run_hgnn)
    ap.add_argument("--hgnn", default=None,
                    choices=["han", "rgcn", "magnn", "gcn"],
                    help="serve an HGNN model instead of an LM")
    ap.add_argument("--dataset", default="imdb",
                    choices=["imdb", "acm", "dblp", "reddit"])
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused GAT-NA / segment-SpMM Pallas kernels "
                         "(TPU backend)")
    ap.add_argument("--degree-buckets", type=int, default=0,
                    help=">1: degree-bucketed padded NA layout "
                         "(HAN metapaths + RGCN per-relation tables)")
    ap.add_argument("--partitions", type=int, default=0,
                    help=">=1: graph-partitioned execution with that many "
                         "edge-cut partitions (per-partition FP/NA + explicit "
                         "halo feature exchange; repro.dist.partition)")
    ap.add_argument("--layers", type=int, default=1,
                    help=">1: stack that many FP->NA->SA layers (per-layer "
                         "params; the graph-side index tables are built once "
                         "and reused; partitioned runs re-exchange updated "
                         "halo features every layer)")
    ap.add_argument("--cache-rows", type=int, default=0,
                    help=">=1: hot-feature residency — keep that many "
                         "degree-ordered rows per source type resident "
                         "(repro.core.residency); NA gathers serve hot rows "
                         "from the cache section, partitioned runs skip the "
                         "halo exchange for hot rows, and serving keeps a "
                         "live per-type cache over the sampled frontier")
    ap.add_argument("--overlap", type=int, default=0,
                    help=">=1: async stage-graph schedule with that "
                         "in-flight dispatch depth — halo exchange overlaps "
                         "owned-rows NA, per-metapath NA stages dispatch "
                         "concurrently, and serving prefetches the next "
                         "step's sample while the device computes "
                         "(1 = serial-degenerate parity baseline)")
    ap.add_argument("--fanout", type=int, default=0,
                    help=">=1: request-path serving — neighbor-sampled "
                         "minibatch inference (per-hop fan-out cap) through "
                         "the slot-based continuous-batching engine; "
                         "--requests/--slots/--slot-targets size the queue")
    ap.add_argument("--slot-targets", type=int, default=4,
                    help="target vertices each slot contributes per serving "
                         "step (HGNN serving mode)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: expired requests complete "
                         "PARTIAL with the rows served so far (HGNN serving "
                         "resilience)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-step SLO target: walls breaching it drive the "
                         "degradation ladder (smaller chunks + smaller "
                         "warmed rungs; restores when pressure drops)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission bound: requests beyond this queue depth "
                         "are shed (status REJECTED) instead of growing the "
                         "backlog")
    ap.add_argument("--inject-faults", type=int, default=None,
                    help="seed a deterministic fault schedule (transient + "
                         "persistent sampler/forward faults, injected "
                         "latency, partition loss) through the serve loop — "
                         "the chaos-smoke harness")
    ap.add_argument("--slo-signal", choices=("observed", "injected"),
                    default="observed",
                    help="wall feeding the SLO comparison: 'observed' = real "
                         "step wall + injected latency (production); "
                         "'injected' = the fault schedule's latency alone — "
                         "replay-deterministic degradation for chaos smokes")
    ap.add_argument("--fuse-na-sa", action="store_true",
                    help="fused NA→SA epilogue: SA pass-1 scores accumulate "
                         "inside the NA kernel (stacked layout)")
    ap.add_argument("--characterize", action="store_true",
                    help="print the per-stage FLOPs/bytes/roofline records")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    if args.hgnn:
        run_hgnn(args)
        return

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve launcher covers decoder-only archs; "
                         "see examples/serve_decode.py for enc-dec")
    params = init_lm_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_tokens)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_tokens=args.max_tokens, temperature=args.temperature)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    for i, r in enumerate(done):
        print(f"req{i}: {r.out_tokens}")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
