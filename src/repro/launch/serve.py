"""Serving launcher: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 8 --max-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.nn.transformer import init_lm_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve launcher covers decoder-only archs; "
                         "see examples/serve_decode.py for enc-dec")
    params = init_lm_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_tokens)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_tokens=args.max_tokens, temperature=args.temperature)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    for i, r in enumerate(done):
        print(f"req{i}: {r.out_tokens}")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
