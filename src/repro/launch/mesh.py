"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; smoke tests must keep
seeing 1 device).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — 'pod' is pure DP
    across slices; gradient all-reduce is the only cross-pod collective."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 2, model: int = 2):
    """Small mesh over however many local devices exist (tests)."""
    n = data * model
    devs = jax.devices()[:n]
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))
