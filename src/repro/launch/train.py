"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt

On a real cluster: one process per host, ``jax.distributed.initialize()``
first (see scripts/launch_multipod.sh), then the same code path — the mesh
spans all hosts' devices and each host feeds its own data shard.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.data.loader import PrefetchLoader, synth_batch
from repro.dist.sharding import use_mesh
from repro.launch.steps import build_train_step
from repro.train import checkpoint as ckpt
from repro.train.elastic import StepTimer
from repro.train.optimizer import build_optimizer
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-runnable reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeConfig("custom", args.seq_len, args.batch, "train")
    opt = build_optimizer(cfg, total_steps=max(args.steps, 10))
    step_fn = make_train_step(cfg, opt, n_microbatches=args.microbatches)
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    state = init_train_state(jax.random.key(0), cfg, opt)
    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore(args.ckpt_dir, state)
        start = int(state.step)
        print(f"resumed from step {start}")

    saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    timer = StepTimer()
    loader = PrefetchLoader(cfg, shape, start_step=start)
    try:
        for i in range(start, start + args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
            t0 = time.time()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            straggler = timer.observe(dt)
            print(f"step {i:5d} loss {loss:8.4f} gnorm "
                  f"{float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f} ms"
                  + ("  [straggler]" if straggler else ""), flush=True)
            if saver and (i + 1) % args.ckpt_every == 0:
                saver.submit(state, i + 1)
        if saver:
            saver.submit(state, start + args.steps)
    finally:
        loader.close()
        if saver:
            saver.close()


if __name__ == "__main__":
    main()
