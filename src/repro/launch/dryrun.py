import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and dump memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/

Per cell this writes JSON with:
  memory_analysis  (bytes/device: args, outputs, temps, code)
  cost_analysis    (HLO flops / bytes accessed, per device)
  collective_bytes (parsed from the compiled per-device HLO)
  kernel-class breakdown (repro.core.characterize) + roofline terms
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_archs, long_context_supported
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def run_cell(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool,
             overrides=None):
    from repro.core.characterize import analyze_compiled  # heavy import after flags

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    if overrides:
        cfg = cfg.replace(**overrides)
    t0 = time.time()
    built = build_step(cfg, shape, mesh)
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings,
                     donate_argnums=built.donate)
    lowered = jitted.lower(*built.in_specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    report = analyze_compiled(compiled, cfg=cfg, shape=shape, n_chips=n_chips)
    report.update({
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_device_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes) / 2 ** 30, 3),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
    })
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf experiments)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    import os as _os

    _os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = json.loads(args.override) if args.override else None

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            shape = SHAPES[shape_name]
            if shape.name == "long_500k" and not long_context_supported(cfg):
                print(f"SKIP {arch} x {shape_name}: full attention at 500k "
                      f"(documented in DESIGN.md §4)")
                continue
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = _os.path.join(args.out, tag + ".json")
                if _os.path.exists(path) and overrides is None:
                    print(f"CACHED {tag}")
                    continue
                print(f"RUN {tag} ...", flush=True)
                try:
                    rep = run_cell(cfg, shape, mp, overrides)
                    with open(path, "w") as f:
                        json.dump(rep, f, indent=1)
                    print(f"OK  {tag}: peak={rep['memory']['peak_device_gib']}GiB "
                          f"compile={rep['compile_s']}s "
                          f"bound={rep['roofline']['bound']}", flush=True)
                except Exception:
                    failures += 1
                    print(f"FAIL {tag}\n{traceback.format_exc()}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
