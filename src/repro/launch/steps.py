"""Step builders: (arch config, shape, mesh) -> jit-able step + shardings.

One entry per shape kind:
  train_4k     -> train_step(state, batch)
  prefill_32k  -> serve_prefill(params, batch) -> (logits, caches)
  decode_*     -> serve_decode(params, token, caches, pos) -> (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.specs import cache_shardings, input_shardings, input_specs
from repro.dist.param_sharding import param_specs
from repro.dist.sharding import BATCH, MODEL, use_mesh
from repro.train.optimizer import build_optimizer
from repro.train.train_step import (
    TrainState,
    init_train_state,
    make_train_step,
    state_shardings,
)


class BuiltStep(NamedTuple):
    fn: Any  # callable to jit
    in_specs: Tuple  # abstract inputs (ShapeDtypeStructs), positional
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple  # positional indices to donate


def _logits_sharding(mesh: Mesh, shape) -> NamedSharding:
    """[B, S, V] -> batch over (pod,data), vocab over model, with the
    divisibility guard (mamba2's 50280 / seamless' 256206 vocab, batch=1)."""
    from repro.dist.sharding import resolve_spec

    return NamedSharding(mesh, resolve_spec(shape, (BATCH, None, MODEL), mesh))


def _params_abstract(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.nn.encdec import init_encdec_params

        return jax.eval_shape(lambda: init_encdec_params(jax.random.key(0), cfg))
    from repro.nn.transformer import init_lm_params

    return jax.eval_shape(lambda: init_lm_params(jax.random.key(0), cfg))


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     n_microbatches: int = 0) -> BuiltStep:
    opt = build_optimizer(cfg)
    step_fn = make_train_step(
        cfg, opt, n_microbatches=n_microbatches or cfg.n_microbatches)

    def fn(state, batch):
        with use_mesh(mesh):
            return step_fn(state, batch)

    state_abs = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, opt))
    st_sh = state_shardings(state_abs, opt, mesh, fsdp=cfg.fsdp, fsdp_experts=cfg.fsdp_experts)
    batch_abs = input_specs(cfg, shape)
    batch_sh = input_shardings(cfg, shape, mesh)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}
    return BuiltStep(
        fn=fn,
        in_specs=(state_abs, batch_abs),
        in_shardings=(st_sh, batch_sh),
        out_shardings=(st_sh, metrics_sh),
        donate=(0,),
    )


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> BuiltStep:
    if cfg.family == "encdec":
        from repro.nn.encdec import encdec_prefill, init_encdec_params

        def fn(params, batch):
            with use_mesh(mesh):
                return encdec_prefill(params, cfg, batch["frames"], batch["tokens"])
    else:
        from repro.nn.transformer import lm_prefill

        def fn(params, batch):
            with use_mesh(mesh):
                return lm_prefill(params, cfg, batch["tokens"],
                                  batch.get("extra_embeds"))

    params_abs = _params_abstract(cfg)
    p_sh = param_specs(params_abs, mesh, fsdp=cfg.fsdp, fsdp_experts=cfg.fsdp_experts)
    batch_abs = input_specs(cfg, shape)
    batch_sh = input_shardings(cfg, shape, mesh)
    out_abs = jax.eval_shape(fn, params_abs, batch_abs)
    logits_sh = _logits_sharding(mesh, out_abs[0].shape)
    caches_sh = cache_shardings(cfg, mesh, out_abs[1])
    return BuiltStep(
        fn=fn,
        in_specs=(params_abs, batch_abs),
        in_shardings=(p_sh, batch_sh),
        out_shardings=(logits_sh, caches_sh),
        donate=(),
    )


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> BuiltStep:
    if cfg.family == "encdec":
        from repro.nn.encdec import encdec_decode_step

        def fn(params, token, caches, pos):
            with use_mesh(mesh):
                return encdec_decode_step(params, cfg, token, caches, pos)
    else:
        from repro.nn.transformer import lm_decode_step

        def fn(params, token, caches, pos):
            with use_mesh(mesh):
                return lm_decode_step(params, cfg, token, caches, pos)

    params_abs = _params_abstract(cfg)
    p_sh = param_specs(params_abs, mesh, fsdp=cfg.fsdp, fsdp_experts=cfg.fsdp_experts)
    ins = input_specs(cfg, shape)
    ins_sh = input_shardings(cfg, shape, mesh)
    out_abs = jax.eval_shape(fn, params_abs, ins["token"], ins["caches"], ins["pos"])
    logits_sh = _logits_sharding(mesh, out_abs[0].shape)
    return BuiltStep(
        fn=fn,
        in_specs=(params_abs, ins["token"], ins["caches"], ins["pos"]),
        in_shardings=(p_sh, ins_sh["token"], ins_sh["caches"], ins_sh["pos"]),
        out_shardings=(logits_sh, ins_sh["caches"]),
        donate=(2,),
    )


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh)


def jit_step(built: BuiltStep):
    return jax.jit(
        built.fn,
        in_shardings=built.in_shardings,
        out_shardings=built.out_shardings,
        donate_argnums=built.donate,
    )
