"""Hot-feature residency: a degree-ordered feature cache for the NA gathers.

The paper's characterization (and ours — ``BENCH_hgnn.json``
``avg_na_share_pct`` ≈ 48%) shows Neighbor Aggregation is memory-bound on
re-gathering the same high-degree rows from HBM: across metapaths, across
partitions (halo rows), across layers (layer *l*'s carried target table is
re-gathered by layer *l+1*'s NA), and across serving requests.  HiHGNN
(arXiv:2307.12765) shows exploiting exactly this reusability is the largest
available win.

One subsystem, three consumers, all driven by the frozen
:class:`~repro.core.plan.ResidencySpec` on the plan:

* **Single-device batches** (:func:`build_tables` + :func:`apply`): per
  source type, the top-``cache_rows`` rows by *reference count* under the
  plan's own index tables (degree ordering) become the hot set.  The
  neighbor tables are remapped through a LUT so hot references address a
  contiguous cache section appended to the source pool
  (``pool = concat(h, h[hot])`` — the executor's residency dispatch arm);
  the section is a bitwise row copy, so outputs are bit-exact by
  construction.  The hot set and remap are computed once from the
  layer-invariant index tables, so every layer of an L-layer stack reuses
  the same resident rows (the HiHGNN inter-layer reuse: only layer 0 pays
  the cache fill).

* **Partitioned batches** (:func:`partition_overlay`): hot sets come from
  the *unpartitioned* tables (global degree ordering, before
  ``partition_batch`` relabels).  Each partition keeps a local cache of the
  hot rows it can serve (``hot_flat``), and every halo-table entry whose
  global vertex is hot carries its cache slot (``halo_slot``) — the
  executor's ``gather_halo`` overlays those rows from the cache so they
  skip the exchange (``characterize`` reports the saved halo bytes).

* **Serving** (:class:`HotRowCache`): the engine keeps a *live* cache per
  type, degree-ordered by the graph's source degrees
  (:func:`graph_degrees`), accessed by every step's sampled frontier with
  the in-flight targets pinned.  Admission/eviction is deterministic: a
  miss is admitted only if it outranks the lowest-priority unpinned
  resident in ``(degree, -row_id)`` order, which is also the evictee.

Everything here is host-side numpy; the device-side consumers are the
executor's dispatch arms and ``kernels/feature_cache.cached_gather``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.plan import StagePlan


# ---------------------------------------------------------------------------
# hot-set selection (static, degree-ordered)
# ---------------------------------------------------------------------------


def hot_set(counts: np.ndarray, capacity: int) -> np.ndarray:
    """Top-``capacity`` row ids by ``(count desc, id asc)`` — slot 0 is the
    hottest row.  Deterministic: ties break toward the smaller row id, and
    the capacity clamps to the population."""
    n = len(counts)
    c = int(min(max(capacity, 0), n))
    order = np.lexsort((np.arange(n), -np.asarray(counts)))
    return order[:c].astype(np.int32)


def _populations(plan: StagePlan, batch: Dict) -> Dict[str, int]:
    if "feats" in batch:
        return {t: int(f.shape[0]) for t, f in batch["feats"].items()}
    # GCN: one homogeneous table
    return {plan.target: int(batch["x"].shape[0])}


def _iter_gathers(plan: StagePlan, batch: Dict) -> Iterator[Tuple]:
    """Yield ``(src_type, idx_array, valid_mask_or_None)`` for every NA
    gather table in a prepared (unpartitioned) batch, in deterministic
    order.  ``idx_array`` and the mask always share a shape; ``None`` means
    every entry is a real reference (edge-list layouts)."""
    kind, layout = plan.na.kind, plan.na.layout
    if kind == "gat":
        t = plan.target
        if layout == "csr":
            for _seg, idx in batch["edges"]:
                yield t, idx, None
        elif layout == "bucketed":
            for bks in batch["buckets"]:
                for _row_ids, nbr, mask in bks:
                    yield t, nbr, mask
        else:  # stacked
            yield t, batch["nbr"], batch["mask"]
    elif kind == "mean":
        for key in sorted(batch["rels"]):
            s = key[0]
            rel = batch["rels"][key]
            if layout == "csr":
                yield s, rel[1], None
            elif layout == "bucketed":
                for _row_ids, nbr, mask in rel:
                    yield s, nbr, mask
            else:  # padded
                yield s, rel[0], rel[1]
    elif kind == "instance":
        for (nodes, mask), types in zip(batch["instances"], plan.metapaths):
            for j, ty in enumerate(types):
                yield ty, nodes[..., j], mask
    elif kind == "gcn":
        yield plan.target, batch["idx"], None
    else:  # pragma: no cover - plan validation catches this earlier
        raise ValueError(f"no residency gather walk for NA kind {kind!r}")


@dataclass
class ResidencyTables:
    """Host-side product of :func:`build_tables` for one prepared batch."""

    hot: Dict[str, np.ndarray]  # type -> [C_t] hot row ids, degree-ordered
    rank: Dict[str, np.ndarray]  # type -> [N_t] row -> cache slot (-1 cold)
    lut: Dict[str, np.ndarray]  # type -> [N_t] row -> extended-pool index
    counts: Dict[str, np.ndarray]  # type -> [N_t] reference counts
    populations: Dict[str, int]
    cache_rows: int


def build_tables(plan: StagePlan, batch: Dict) -> ResidencyTables:
    """Reference-count every NA gather table and select per-type hot sets.

    Runs on the *unpartitioned* batch in both modes — the degree ordering
    is a global-graph property, not a per-partition one."""
    spec = plan.residency
    pops = _populations(plan, batch)
    counts: Dict[str, np.ndarray] = {}
    for t, idx, mask in _iter_gathers(plan, batch):
        a = np.asarray(idx)
        a = a[np.asarray(mask) > 0] if mask is not None else a.reshape(-1)
        c = counts.get(t)
        if c is None:
            c = np.zeros(pops[t], np.int64)
        counts[t] = c + np.bincount(a.astype(np.int64), minlength=pops[t])
    hot = {t: hot_set(c, spec.cache_rows) for t, c in counts.items()}
    rank, lut = {}, {}
    for t, ht in hot.items():
        n = pops[t]
        r = np.full(n, -1, np.int32)
        r[ht] = np.arange(len(ht), dtype=np.int32)
        rank[t] = r
        m = np.arange(n, dtype=np.int32)
        m[ht] = n + np.arange(len(ht), dtype=np.int32)
        lut[t] = m
    return ResidencyTables(hot=hot, rank=rank, lut=lut, counts=counts,
                           populations=pops, cache_rows=spec.cache_rows)


def _count_hits(plan: StagePlan, batch: Dict,
                tables: ResidencyTables) -> Dict[str, int]:
    """Deterministic hit/miss counters over one full pass of the gather
    tables: hits = valid references addressing a hot row, and
    ``hits + misses == rows`` (total gathered rows) by construction."""
    hits = rows = 0
    for t, idx, mask in _iter_gathers(plan, batch):
        a = np.asarray(idx)
        a = a[np.asarray(mask) > 0] if mask is not None else a.reshape(-1)
        rows += int(a.size)
        hits += int((tables.rank[t][a] >= 0).sum())
    return {
        "hits": hits,
        "misses": rows - hits,
        "rows": rows,
        "cache_rows": int(sum(len(h) for h in tables.hot.values())),
    }


def apply(plan: StagePlan, batch: Dict, tables: ResidencyTables) -> Dict:
    """Single-device residency: remap every NA index table through the LUT
    (hot references -> the cache section appended to the source pool) and
    attach ``batch["residency"]`` (hot sets for the executor's pool arm +
    the deterministic counters).  Pad entries remap too — they are
    zero-weighted by their masks in every aggregation, so the substitution
    is bit-exact."""
    counters = _count_hits(plan, batch, tables)
    lut = tables.lut
    out = dict(batch)

    def remap(t, a):
        if t not in lut:
            return a
        return jnp.asarray(lut[t][np.asarray(a)])

    kind, layout = plan.na.kind, plan.na.layout
    if kind == "gat":
        t = plan.target
        if layout == "csr":
            out["edges"] = [(seg, remap(t, idx))
                            for seg, idx in batch["edges"]]
        elif layout == "bucketed":
            out["buckets"] = [
                [(rid, remap(t, nbr), m) for rid, nbr, m in bks]
                for bks in batch["buckets"]
            ]
        else:
            out["nbr"] = remap(t, batch["nbr"])
    elif kind == "mean":
        rels = {}
        for key, rel in batch["rels"].items():
            s = key[0]
            if layout == "csr":
                rels[key] = (rel[0], remap(s, rel[1]))
            elif layout == "bucketed":
                rels[key] = [(rid, remap(s, nbr), m)
                             for rid, nbr, m in rel]
            else:
                rels[key] = (remap(s, rel[0]), rel[1])
        out["rels"] = rels
    elif kind == "instance":
        inst = []
        for (nodes, mask), types in zip(batch["instances"], plan.metapaths):
            nn = np.asarray(nodes).copy()
            for j, ty in enumerate(types):
                if ty in lut:
                    nn[..., j] = lut[ty][nn[..., j]]
            inst.append((jnp.asarray(nn), mask))
        out["instances"] = inst
    elif kind == "gcn":
        out["idx"] = remap(plan.target, batch["idx"])
    out["residency"] = {
        "hot": {t: jnp.asarray(h, jnp.int32) for t, h in tables.hot.items()},
        "counters": counters,
    }
    return out


def partition_overlay(tables: ResidencyTables, batch: Dict) -> Dict:
    """Partitioned residency: build the per-partition overlay tables from
    an already-partitioned batch.

    ``hot_flat[t]``  [C] flat own-order indices (``owner * n_max + local``)
                     of the hot rows — each partition-local cache row is a
                     bitwise copy of an owned row somewhere in the pod.
    ``halo_slot[t]`` [K, H_max] cache slot per halo-table entry, -1 when the
                     entry's global vertex is cold (or a pad).  The
                     executor's ``gather_halo`` overlays slot >= 0 entries
                     from the cache, so hot halo rows skip the exchange.
    """
    part = batch["part"]
    hot_flat: Dict = {}
    halo_slot: Dict = {}
    hits = rows = 0
    for t, hot_g in tables.hot.items():
        if t not in part.get("own", {}):
            continue
        own = np.asarray(part["own"][t])
        om = np.asarray(part["own_mask"][t]).reshape(-1) > 0
        of = own.reshape(-1)
        n_t = tables.populations[t]
        g2f = np.full(n_t, -1, np.int64)
        g2f[of[om]] = np.nonzero(om)[0]
        hf = g2f[hot_g]
        assert (hf >= 0).all(), f"hot rows of type {t!r} must all be owned"
        rank = tables.rank[t]
        hs = np.asarray(part["halo_src"][t])
        hm = np.asarray(part["halo_mask"][t]) > 0
        halo_g = of[hs.reshape(-1)].reshape(hs.shape)
        slot = np.where(hm, rank[halo_g], -1).astype(np.int32)
        hot_flat[t] = jnp.asarray(hf, jnp.int32)
        halo_slot[t] = jnp.asarray(slot)
        hits += int((slot >= 0).sum())
        rows += int(hm.sum())
    return {
        "hot_flat": hot_flat,
        "halo_slot": halo_slot,
        "counters": {
            "hits": hits,
            "misses": rows - hits,
            "rows": rows,
            "cache_rows": int(sum(len(h) for h in tables.hot.values())),
        },
    }


# ---------------------------------------------------------------------------
# the live cache (serving's per-step sampled frontier)
# ---------------------------------------------------------------------------


def graph_degrees(hg) -> Dict[str, np.ndarray]:
    """Per-type source degrees — how often each vertex is gathered as a
    neighbor source across every relation.  The serving cache's priority
    ordering (a degree proxy for the request-time reference counts)."""
    deg = {t: np.zeros(n, np.int64) for t, n in hg.node_counts.items()}
    for (s, _r, _d), a in hg.relations.items():
        deg[s] += np.asarray(a.sum(axis=1)).reshape(-1).astype(np.int64)
    return deg


class HotRowCache:
    """Deterministic degree-priority hot-row cache (host-side simulator and
    the serving engine's live per-type cache).

    Priority of row ``r`` is ``(degree[r], -r)`` — higher is better, and no
    two rows tie.  On a miss with a full cache, the candidate is admitted
    only if it outranks the lowest-priority *unpinned* resident, which is
    evicted; pinned rows are never evicted.  Replaying the same access
    trace therefore always reproduces the same resident set and counters.
    """

    def __init__(self, capacity: int, degree: np.ndarray):
        self.degree = np.asarray(degree)
        self.capacity = int(min(max(capacity, 0), len(self.degree)))
        self.resident: set = set()
        self.pinned: set = set()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    def _prio(self, r: int) -> Tuple[int, int]:
        return (int(self.degree[r]), -int(r))

    def access(self, row) -> bool:
        """One gather of ``row``: returns True on a cache hit; a miss runs
        the deterministic admission/eviction policy."""
        row = int(row)
        if row in self.resident:
            self.hits += 1
            return True
        self.misses += 1
        if self.capacity == 0:
            return False
        if len(self.resident) < self.capacity:
            self.resident.add(row)
            self.inserts += 1
            return False
        unpinned = [r for r in self.resident if r not in self.pinned]
        if not unpinned:
            return False  # everything pinned by the in-flight batch
        victim = min(unpinned, key=self._prio)
        if self._prio(row) > self._prio(victim):
            self.resident.discard(victim)
            self.evictions += 1
            self.resident.add(row)
            self.inserts += 1
        return False

    def access_many(self, rows) -> Tuple[int, int]:
        h0, m0 = self.hits, self.misses
        for r in np.asarray(rows).reshape(-1):
            self.access(r)
        return self.hits - h0, self.misses - m0

    def pin(self, rows) -> None:
        self.pinned.update(int(r) for r in np.asarray(rows).reshape(-1))

    def unpin(self, rows) -> None:
        self.pinned.difference_update(
            int(r) for r in np.asarray(rows).reshape(-1))

    @property
    def counters(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "rows": self.hits + self.misses,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "resident": len(self.resident),
            "capacity": self.capacity,
        }
