"""Stage 4 — Semantic Aggregation (SA).

Baseline (DGL-faithful): takes the per-metapath NA results as a *list* and
explicitly stacks them — this materializes the DR-Type concat
(CatArrayBatchedCopy) the paper measures at 17.5% of SA time.

Optimized (guideline §5): the NA stage already produced a stacked ``[P,N,D]``
tensor (inter-subgraph parallel layout), so SA runs concat-free.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def init_semantic_attention(rng: jax.Array, d_in: int, d_hidden: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(rng)
    return {
        "W": jax.random.normal(k1, (d_in, d_hidden), jnp.float32) / np.sqrt(d_in),
        "b": jnp.zeros((d_hidden,), jnp.float32),
        "q": jax.random.normal(k2, (d_hidden,), jnp.float32) / np.sqrt(d_hidden),
    }


def semantic_attention(
    p: Dict[str, jax.Array], z: jax.Array, mask: jax.Array = None
) -> jax.Array:
    """HAN-style semantic attention. ``z``: [P, N, D] -> [N, D].

    DM-Type (z @ W), EW-Type (tanh, mul, reduce) — exactly the kernel mix the
    paper reports for SA.

    ``mask`` ([N], optional): row validity for sampled minibatches — the
    per-metapath score mean runs over the real (target + frontier) rows
    only, so rung padding never shifts the semantic weights.  With an
    all-ones mask the masked mean is bitwise the plain ``mean(axis=1)``
    (x·1.0 is exact, same reduction order, same divisor), which is what the
    full-fan-out parity rows rely on.
    """
    s = jnp.tanh(z @ p["W"] + p["b"])  # [P, N, H]   DM + EW
    sc = jnp.einsum("pnh,h->pn", s, p["q"])  # [P, N]
    if mask is None:
        w = sc.mean(axis=1)  # [P]  Reduce
    else:
        w = (sc * mask[None, :]).sum(axis=1) / jnp.maximum(mask.sum(), 1.0)
    beta = jax.nn.softmax(w)  # [P]
    return jnp.einsum("p,pnd->nd", beta, z)  # weighted Reduce


def semantic_attention_list(
    p: Dict[str, jax.Array], z_list: List[jax.Array], mask: jax.Array = None
) -> jax.Array:
    """Baseline SA: explicit stack (DR-Type concat) then attention."""
    z = jnp.stack(z_list, axis=0)  # DR-Type: CatArrayBatchedCopy analogue
    return semantic_attention(p, z, mask)


def semantic_attention_partitioned(
    p: Dict[str, jax.Array], z: jax.Array, mask: jax.Array
) -> jax.Array:
    """Semantic attention over partition-local stacks.

    ``z``: [K, P, n, D] per-partition NA outputs (padded rows masked by
    ``mask`` [K, n]).  Pass 1 reduces to per-partition partial score sums —
    the cross-partition reduce of a [K, P] array is the only communication —
    and the global masked mean equals the unpartitioned ``mean(axis=1)``
    exactly (pad rows contribute nothing).  Pass 2 (the weighted combine)
    stays partition-local.  Returns [K, n, D].
    """
    s = jnp.tanh(z @ p["W"] + p["b"])  # [K, P, n, H]
    sc = jnp.einsum("kpnh,h->kpn", s, p["q"]) * mask[:, None, :]
    w = sc.sum(axis=(0, 2)) / jnp.maximum(mask.sum(), 1.0)  # [P] global mean
    beta = jax.nn.softmax(w)
    return jnp.einsum("p,kpnd->knd", beta, z)  # partition-local combine


def semantic_sum(z: jax.Array) -> jax.Array:
    """RGCN SA: plain sum across relations (paper: Reduce kernel, no attention)."""
    return z.sum(axis=0)


def semantic_sum_list(z_list: List[jax.Array]) -> jax.Array:
    acc = z_list[0]
    for z in z_list[1:]:
        acc = acc + z
    return acc
