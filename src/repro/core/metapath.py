"""Subgraph Build (stage 1 of the paper's four-stage HGNN semantic).

Runs on the host (numpy/scipy) before inference — exactly as the paper
observes for DGL.  Produces device-ready layouts:

* ``PaddedSubgraph`` — degree-capped padded neighbor lists ``[N, K]``.  This is
  the TPU adaptation of the GPU's CSR SpMM: irregular gather becomes a dense
  blocked gather + masked reduction (reduction tree) that tiles into VMEM.
* ``CSRSubgraph`` — flat CSR (indptr/indices) for the segment-sum execution
  path (the DGL-faithful baseline we characterize).
* ``InstanceBatch`` — MAGNN metapath *instances* (node id per path position),
  sampled with a per-node cap.

Stacking: HAN/MAGNN aggregate per metapath then across metapaths.  The
baseline keeps one subgraph per metapath (and the Semantic Aggregation stage
pays the paper's DR-Type concat); the optimized path stacks all subgraphs into
``[P, N, K]`` up front (inter-subgraph parallelism, guideline §5) so no
rearrangement ever happens on device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np
import scipy.sparse as sp

from repro.core.hgraph import HeteroGraph, metapath_adjacency


@dataclass
class PaddedSubgraph:
    """Degree-capped padded neighbor layout for one metapath subgraph."""

    nbr: np.ndarray  # [N, K] int32 neighbor ids (0-padded)
    mask: np.ndarray  # [N, K] float32 {0,1}
    node_path: List[str]

    @property
    def n_nodes(self) -> int:
        return self.nbr.shape[0]

    @property
    def max_degree(self) -> int:
        return self.nbr.shape[1]


@dataclass
class DegreeBuckets:
    """Degree-bucketed padded layout: rows binned into a few K-caps.

    One ``K = max_degree`` pad wastes reduction-tree steps on every
    low-degree row (power-law graphs: most rows).  Binning rows into 2-3
    buckets with per-bucket caps ``K_b`` cuts the padded edge count
    (``sum_b n_b * K_b`` vs ``N * K``) while keeping each bucket a dense
    TPU-friendly ``[n_b, K_b]`` tile.  ``row_ids[b]`` maps bucket rows back
    to the original node order (the NA dispatch scatters outputs through it).
    Empty buckets are dropped at build time.
    """

    row_ids: List[np.ndarray]  # per bucket: [n_b] int32 original row ids
    nbr: List[np.ndarray]  # per bucket: [n_b, K_b] int32
    mask: List[np.ndarray]  # per bucket: [n_b, K_b] float32
    n_nodes: int
    node_path: List[str]

    @property
    def n_buckets(self) -> int:
        return len(self.row_ids)

    @property
    def padded_edges(self) -> int:
        return sum(nb.size for nb in self.nbr)


@dataclass
class CSRSubgraph:
    indptr: np.ndarray  # [N+1] int32
    indices: np.ndarray  # [nnz] int32
    node_path: List[str]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1


@dataclass
class InstanceBatch:
    """MAGNN metapath instances: ``nodes[i, j]`` = node id at position j of
    instance i (position 0 = target).  ``types`` gives the node type per
    position.  Instances are grouped per target: ``[N, I, L+1]`` with mask.
    """

    nodes: np.ndarray  # [N, I, L+1] int32
    mask: np.ndarray  # [N, I] float32
    types: List[str]


def build_padded(
    hg: HeteroGraph,
    node_path: Sequence[str],
    max_degree: int = 64,
    rng: Optional[np.random.Generator] = None,
    add_self_loop: bool = True,
) -> PaddedSubgraph:
    adj = metapath_adjacency(hg, list(node_path))
    if add_self_loop:
        adj = (adj + sp.eye(adj.shape[0], adj.shape[1], format="csr")).tocsr()
        adj.data = np.ones_like(adj.data)
    rng = rng or np.random.default_rng(0)
    n = adj.shape[0]
    nbr = np.zeros((n, max_degree), np.int32)
    mask = np.zeros((n, max_degree), np.float32)
    indptr, indices = adj.indptr, adj.indices
    for u in range(n):
        nbrs = indices[indptr[u] : indptr[u + 1]]
        if len(nbrs) > max_degree:
            nbrs = rng.choice(nbrs, size=max_degree, replace=False)
        k = len(nbrs)
        nbr[u, :k] = nbrs
        mask[u, :k] = 1.0
    return PaddedSubgraph(nbr, mask, list(node_path))


def bucket_padded(
    sub: PaddedSubgraph, n_buckets: int = 3, round_to: int = 8
) -> DegreeBuckets:
    """Bin a padded subgraph's rows into ``n_buckets`` degree buckets.

    Caps are degree quantiles rounded up to a multiple of ``round_to`` (lane
    friendliness); the last cap is always ``max_degree`` so no edge is
    dropped.  Duplicate caps collapse, so fewer buckets than requested can
    come back (e.g. a degree-uniform graph yields one).
    """
    deg = sub.mask.sum(axis=1).astype(np.int64)  # [N]
    caps: List[int] = []
    for i in range(1, n_buckets):
        q = int(np.ceil(np.quantile(deg, i / n_buckets))) if len(deg) else 1
        caps.append(max(round_to, int(np.ceil(max(q, 1) / round_to)) * round_to))
    caps.append(sub.max_degree)
    caps = sorted(set(min(c, sub.max_degree) for c in caps))
    row_ids, nbrs, masks = [], [], []
    assigned = np.zeros(sub.n_nodes, bool)
    for cap in caps:
        rows = np.flatnonzero(~assigned & (deg <= cap))
        assigned[rows] = True
        if len(rows) == 0:
            continue
        row_ids.append(rows.astype(np.int32))
        nbrs.append(sub.nbr[rows, :cap])
        masks.append(sub.mask[rows, :cap])
    return DegreeBuckets(row_ids, nbrs, masks, sub.n_nodes, sub.node_path)


def build_degree_bucketed(
    hg: HeteroGraph,
    node_path: Sequence[str],
    max_degree: int = 64,
    n_buckets: int = 3,
    rng: Optional[np.random.Generator] = None,
) -> DegreeBuckets:
    """Subgraph Build straight into the degree-bucketed layout."""
    return bucket_padded(build_padded(hg, node_path, max_degree, rng),
                         n_buckets=n_buckets)


def build_csr(
    hg: HeteroGraph, node_path: Sequence[str], add_self_loop: bool = True
) -> CSRSubgraph:
    adj = metapath_adjacency(hg, list(node_path))
    if add_self_loop:
        adj = (adj + sp.eye(adj.shape[0], adj.shape[1], format="csr")).tocsr()
        adj.data = np.ones_like(adj.data)
    return CSRSubgraph(
        adj.indptr.astype(np.int32), adj.indices.astype(np.int32), list(node_path)
    )


def stack_padded(subgraphs: List[PaddedSubgraph]) -> "tuple[np.ndarray, np.ndarray]":
    """Stack P subgraphs (same target type) into [P, N, Kmax] — the optimized
    inter-subgraph-parallel layout (no device-side concat)."""
    n = subgraphs[0].n_nodes
    kmax = max(s.max_degree for s in subgraphs)
    p = len(subgraphs)
    nbr = np.zeros((p, n, kmax), np.int32)
    mask = np.zeros((p, n, kmax), np.float32)
    for i, s in enumerate(subgraphs):
        assert s.n_nodes == n, "stacked subgraphs must share the target node set"
        nbr[i, :, : s.max_degree] = s.nbr
        mask[i, :, : s.max_degree] = s.mask
    return nbr, mask


def enumerate_instances(
    hg: HeteroGraph,
    node_path: Sequence[str],
    max_instances: int = 16,
    max_fanout: int = 8,
    rng: Optional[np.random.Generator] = None,
    max_frontier: int = 2_000_000,
) -> InstanceBatch:
    """Sample metapath instances per target node (MAGNN Subgraph Build).

    Full enumeration explodes combinatorially (e.g. DBLP A-P-V-P-A through a
    20-venue hub); MAGNN implementations sample, and so do we.  Fully
    vectorized BFS expansion (no per-row Python loop): per hop each partial
    instance extends by its first ``max_fanout`` CSR neighbors; the frontier
    is down-sampled to ``max_frontier`` rows between hops; a vectorized
    per-target reservoir keeps ``max_instances`` instances.
    """
    rng = rng or np.random.default_rng(0)
    path = list(node_path)
    n_tgt = hg.node_counts[path[0]]
    frontier = np.arange(n_tgt, dtype=np.int64)[:, None]
    for a, b in zip(path[:-1], path[1:]):
        adj = hg.rel(a, b).tocsr()
        rows = frontier[:, -1]
        indptr, indices = adj.indptr, adj.indices
        take = np.minimum(indptr[rows + 1] - indptr[rows], max_fanout)
        total = int(take.sum())
        if total == 0:
            frontier = np.zeros((0, frontier.shape[1] + 1), np.int64)
            break
        rep = np.repeat(np.arange(len(frontier)), take)
        offs = np.arange(total) - np.repeat(np.cumsum(take) - take, take)
        nxt = indices[indptr[rows][rep] + offs].astype(np.int64)
        frontier = np.concatenate([frontier[rep], nxt[:, None]], axis=1)
        if len(frontier) > max_frontier:  # hub-explosion guard
            pick = rng.choice(len(frontier), max_frontier, replace=False)
            frontier = frontier[pick]

    L = len(path)
    nodes = np.zeros((n_tgt, max_instances, L), np.int32)
    mask = np.zeros((n_tgt, max_instances), np.float32)
    if len(frontier):
        frontier = frontier[rng.permutation(len(frontier))]
        order = np.argsort(frontier[:, 0], kind="stable")
        f = frontier[order]
        tgt = f[:, 0]
        counts = np.bincount(tgt, minlength=n_tgt)
        starts = np.cumsum(counts) - counts
        pos = np.arange(len(f)) - np.repeat(starts, counts)
        keep = pos < max_instances
        kept, kpos = f[keep], pos[keep]
        nodes[kept[:, 0], kpos] = kept
        mask[kept[:, 0], kpos] = 1.0
    return InstanceBatch(nodes, mask, path)
