"""Declarative stage plans — each HGNN model's execution as *data*.

The paper's central observation is that every HGNN is the same four-stage
pipeline (Subgraph Build → FP → NA → SA) with per-stage execution patterns
(DM / TB / EW / DR).  Before this module, each model class re-implemented
the same dispatch ladder (baseline CSR vs fused resident vs streaming vs
bucketed vs sharded vs pallas-vs-ref) inside its ``fp``/``na``/``sa``
methods.  A :class:`StagePlan` lifts all of those choices into a frozen
dataclass; one executor (:mod:`repro.core.pipeline`) interprets it.

Real HGNN deployments stack 2–3 of those FP→NA→SA rounds (the follow-up
training characterization, arXiv:2407.11790, measures how the stage mix
shifts with depth), so a :class:`StagePlan` is an *L-layer container*: a
tuple of :class:`LayerPlan`\\ s, each carrying its own FP/NA/SA specs plus
the **inter-layer handoff** — which per-type feature tables layer *l* must
materialize for layer *l+1*'s gathers.  The graph-side index tables
(padded/stacked/bucketed neighbor maps, degree buckets, instance LUTs,
partition halo maps) are layer-invariant and built once in ``prepare()``;
only features flow between layers.

Plan fields double as the sharding contract: ``batch_specs`` /
``param_specs`` are declarative (leaf-name, ndim) → logical-spec tables that
``launch/serve.py`` resolves into :class:`NamedSharding`s — no model-specific
branches in the serving layer either.

Layout / kind vocabulary (the executor's dispatch table):

====== =========== ==================================================
field  value       meaning
====== =========== ==================================================
na.kind   gat        multi-head GAT attention (HAN)
          mean       per-relation mean (RGCN)
          instance   metapath-instance attention (MAGNN)
          gcn        homogeneous 2-layer mean aggregation (GCN)
na.layout csr        DGL-faithful flat edge lists (baseline)
          stacked    padded ``[P, N, K]`` stack, one launch / metapath stack
          bucketed   degree-bucketed padded tiles (per metapath / relation)
          padded     padded ``[N, K]`` per relation (RGCN fused)
          instances  sampled ``[N, I, L]`` instance tables (MAGNN)
sa.kind   attention  HAN-style semantic attention over the stack
          rel_sum    RGCN sum across relations + self loop
          none       single semantic — identity
====== =========== ==================================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.dist.sharding import BATCH, MODEL

# (leaf-or-container key, ndim, logical per-dim spec): a batch/param pytree
# leaf whose dict path contains `key` and whose rank is `ndim` gets the spec;
# everything else replicates.  Resolved by `launch/serve.py:hgnn_shardings`.
ShardRule = Tuple[str, int, Tuple]


@dataclass(frozen=True)
class FPSpec:
    """Stage 2 — Feature Projection (DM-Type dense matmul).

    ``kind`` values:
      per_type  dict of per-type projections (layer 0: raw feats → hidden;
                hidden layers: square re-projections of the carried tables)
      dense     single W on the target table (GCN's combination matmul;
                HAN hidden layers re-projecting the previous SA output)
      identity  no projection — hidden RGCN layers, where the per-relation
                ``w_rel`` / ``w_self`` matmuls inside NA/SA *are* the layer's
                linear transform
    """

    kind: str = "per_type"  # per_type | dense | identity
    sharded: bool = True  # stage-aware shard constraints (no-op off-mesh)
    heads: bool = False  # reshape the target type to [N, H, Dh]


@dataclass(frozen=True)
class NASpec:
    """Stage 3 — Neighbor Aggregation (TB-Type gather + EW attention math)."""

    kind: str  # gat | mean | instance | gcn
    layout: str  # csr | stacked | bucketed | padded | instances
    activation: Optional[str] = None  # elu | relu | None (post-aggregation)
    use_pallas: bool = False  # fused Pallas kernels on the hot loop


@dataclass(frozen=True)
class SASpec:
    """Stage 4 — Semantic Aggregation (EW/Reduce; DR concat in the baseline)."""

    kind: str  # attention | rel_sum | none
    stacked: bool = True  # concat-free [P, N, D] input vs per-metapath list
    # Fused NA→SA epilogue (paper guideline: inter-stage data reuse): the
    # semantic-score pass-1 partial accumulates inside the NA kernel while
    # each z tile is still in VMEM, eliminating one full [P, N, D] HBM read.
    # The executor honours it only on the stacked layout.
    fuse_epilogue: bool = False


@dataclass(frozen=True)
class HeadSpec:
    """Classifier head."""

    kind: str = "linear"  # linear (z @ W) | select_linear (z[target] @ W)
    target: Optional[str] = None  # node type for select_linear
    param: str = "cls"  # which parameter leaf holds the classifier matrix


@dataclass(frozen=True)
class PartitionSpec:
    """Graph-partitioned multi-host execution (``repro.dist.partition``).

    The vertex/feature tables are split into ``k`` edge-cut partitions
    (metapath-aware target assignment, reference-majority source assignment);
    FP and NA run per-partition on local shards, and the only communication
    is the explicit halo feature exchange (``gather_halo`` stage) between
    them.  ``k`` rides the leading array dim of every partitioned batch
    table and shards over the BATCH axes (``PARTITION_BATCH_SPECS``).
    """

    k: int  # number of graph partitions (>= 1; 1 = trivial, empty halos)
    # halo exchange implementation: "auto" = shard_map all-gather when the
    # mesh's BATCH axes divide k, flat gather otherwise; "xla" forces the
    # flat gather (GSPMD resolves the cross-shard traffic from constraints).
    halo: str = "auto"
    # Pad the per-type partition tables to assignment-independent capacities
    # (n_max = ceil(n_type / k) rows per partition, h_max = n_type halo
    # rows) instead of the data-dependent maxima.  The pad rows carry
    # own_mask = 0 and zero features, so they contribute nothing — outputs
    # stay bit-exact — but every sampled batch of the same ladder rung now
    # partitions to identical shapes, so the jitted serve forward never
    # re-traces.  Off by default: full-batch partitioned runs keep the
    # tight data-dependent shapes (and their committed bench records).
    static_shapes: bool = False


@dataclass(frozen=True)
class SampleSpec:
    """Request-path neighbor sampling (``repro.serve.sampler``).

    Serving traffic arrives as requests — "classify these target vertices" —
    not as a full-graph forward.  A plan that carries a ``SampleSpec``
    declares that its batches may be *sampled minibatches*: for a set of
    target vertices the sampler extracts the k-hop / per-metapath
    neighborhood, relabels it into the plan's own NA layout (stacked /
    bucketed / per-relation padded / instance tables), and pads the result
    to a rung of the shape ``ladder`` so the jitted executor never
    recompiles past warmup.

    ``fanout``    per-hop neighbor cap (per metapath / relation); the
                  effective padded width is ``min(fanout, cfg.max_degree)``
                  (``cfg.max_instances`` for MAGNN) and is shape-static.
    ``ladder``    tuple of ``(t_cap, f_cap)`` rungs, small→large: ``t_cap``
                  bounds the targets per batch (engine-side chunking),
                  ``f_cap`` the per-type local vertex tables (clamped to
                  the type's population at sample time).  A batch is padded
                  to the smallest rung that fits; overflow truncates the
                  frontier (farthest-first, counted), never the targets.
    ``seed``      sampler RNG seed — kept equal to ``cfg.seed`` so the
                  sampler's precomputed tables replay ``prepare()``'s exact
                  RNG stream (full fan-out ⇒ bit-exact vs full graph).
    """

    fanout: int
    ladder: Tuple[Tuple[int, int], ...]
    seed: int = 0


@dataclass(frozen=True)
class ResidencySpec:
    """Hot-feature residency (``repro.core.residency``).

    A plan that carries a ``ResidencySpec`` declares that every gather
    path consults a degree-ordered hot-row cache of ``cache_rows`` rows
    per node type: ``prepare()``'s finalize hook selects each type's
    top-``cache_rows`` rows by reference count (degree under the plan's
    own index tables — stacked/bucketed/padded/instances/edge-lists),
    materializes them as a contiguous cache section appended to the
    source pool, and remaps the neighbor tables so hot references read
    the cache section instead of re-gathering the scattered HBM rows.
    The partitioned arm additionally overlays hot halo rows from a
    partition-local cache so they skip the halo exchange, and the
    serving engine runs its per-step sampled frontier against a live
    :class:`~repro.core.residency.HotRowCache` with the in-flight
    targets pinned.

    HiHGNN-style inter-layer reuse falls out of the layer-invariant
    index tables: the hot set and remap are computed once, so layer
    *l*'s carried target table keeps the same rows resident and layer
    *l+1*'s NA gathers them from the cache section, never HBM.

    Bit-exact by construction — the cache holds bitwise row copies and
    the remap is a pure index substitution.
    """

    cache_rows: int  # hot rows kept resident per node type (>= 1)
    # serving: rows addressed by the in-flight slot batch are pinned and
    # never evicted while the step is outstanding
    pin_targets: bool = True


@dataclass(frozen=True)
class ScheduleSpec:
    """Async stage-graph schedule (``StageGraphExecutor.forward_overlapped``).

    The paper characterizes HGNN inference as a chain of stages with
    sharply different bound-ness (FP compute-bound, NA memory-latency
    bound, SA reduction-bound) executing back-to-back with hard barriers.
    A plan that carries a ``ScheduleSpec`` declares that its stage graph
    may instead run as a dependency DAG: the executor derives the edge
    table from the plan (:meth:`~repro.core.pipeline.StageGraphExecutor.
    schedule_edges`) and dispatches independent stages without blocking,
    keeping at most ``depth`` stages in flight on JAX's async dispatch
    stream.  Overlap changes *when* stages run, never *what* they compute
    — every schedule is bit-exact vs the serial ``forward`` loop.

    Three independence sources are exploited (HiHGNN's inter-stage
    overlap, SiHGNN's semantic-graph stage parallelism):

    ``overlap_halo``       partitioned arm: NA is split into an owned-rows
                           pre-gather pass that depends only on FP, so the
                           ``gather_halo`` exchange runs concurrently with
                           it; a where-select merge (bitwise equal to the
                           serial concat-then-gather) joins them before
                           the attention math.
    ``overlap_metapaths``  bucketed / instance NA: per-metapath stages are
                           independent until SA's semantic reduction, so
                           each dispatches as its own stage (single merge
                           point at SA) — which also overlaps metapath
                           p+1's gather issue with metapath p's math.
    ``prefetch``           serving: a host-side thread samples the next
                           step's slot batch while the device runs the
                           current jitted forward (``HGNNServeEngine``).

    ``depth`` bounds the in-flight window: 1 degrades to the serial
    schedule (every stage blocked on dispatch — the parity baseline),
    2 double-buffers, larger values deepen the pipeline.
    """

    depth: int = 2  # max stages in flight (1 = serial-degenerate)
    overlap_halo: bool = True
    overlap_metapaths: bool = True
    prefetch: bool = True

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"ScheduleSpec.depth must be >= 1: {self.depth}")


def default_sample_ladder(
    fanout: int, width: int, hops: int = 1,
    t_rungs: Tuple[int, ...] = (8, 32, 128),
) -> Tuple[Tuple[int, int], ...]:
    """Small automatic ``(t_cap, f_cap)`` ladder for a :class:`SampleSpec`.

    ``width`` is the model's nominal per-target per-hop frontier width
    (metapaths × padded degree for HAN, relations × degree for RGCN, ...);
    ``hops`` the expansion depth.  The ``f_cap`` sizing is a heuristic —
    the sampler clamps it to each type's population and truncates (counted)
    on overflow — while the *rung count* is what matters: one jit
    compilation per rung at warmup, zero after.
    """
    return tuple((t, t * (1 + max(width, 1) * max(hops, 1)))
                 for t in t_rungs)


@dataclass(frozen=True)
class LayerPlan:
    """One FP→NA→SA round of an L-layer stack.

    ``handoff`` names the inter-layer contract — which per-type feature
    tables this layer materializes for the next layer's gathers:

    ============== =======================================================
    handoff        carried state after this layer
    ============== =======================================================
    target         ``{target: z}`` — the metapath graphs are target→target
                   (HAN's stacked subgraphs, GCN's homogeneous graph), so
                   only the target table is ever gathered again
    all            the SA stage already returns every node type's updated
                   table (RGCN's rel_sum updates the whole graph)
    target+carry   the target row is updated from SA; the ``carry`` types
                   (MAGNN's non-target metapath positions) pass through
                   from this layer's FP output and are re-projected by the
                   next layer's FP
    ============== =======================================================
    """

    fp: FPSpec
    na: NASpec
    sa: SASpec
    handoff: str = "target"  # target | all | target+carry
    carry: Tuple[str, ...] = ()  # non-target types forwarded (target+carry)
    # Hot-feature residency for this layer's gathers (None = every gather
    # re-reads HBM).  Layer-uniform — the hot set is computed once from
    # the layer-invariant index tables (see StagePlan.__post_init__).
    residency: Optional[ResidencySpec] = None


@dataclass(frozen=True)
class StagePlan:
    """One model's whole execution, declared as data.

    ``layers`` is the L-layer stack (one :class:`LayerPlan` per FP→NA→SA
    round); the single-layer accessors ``plan.fp`` / ``plan.na`` /
    ``plan.sa`` read layer 0, which is exact for every layer-invariant
    field — NA kind/layout and the SA kind must be uniform across the
    stack (the host-side index tables are built once), and only FP varies
    per layer.  ``metapaths`` carries the static per-metapath node-type
    paths (HAN's subgraph count, MAGNN's per-position gather types) so the
    device batch holds arrays only.
    """

    model: str
    target: str  # target node type (classification rows)
    layers: Tuple[LayerPlan, ...]
    head: HeadSpec
    metapaths: Tuple[Tuple[str, ...], ...] = ()
    batch_specs: Tuple[ShardRule, ...] = ()
    param_specs: Tuple[ShardRule, ...] = (("fp", 2, (None, MODEL)),)
    # Graph-partitioned execution mode (None = single-table execution).
    partition: Optional[PartitionSpec] = None
    # Request-path sampled-minibatch mode (None = full-graph batches only).
    sample: Optional[SampleSpec] = None
    # Async stage-graph schedule (None = strict serial stage loop).
    schedule: Optional[ScheduleSpec] = None

    def __post_init__(self):
        if not self.layers:
            raise ValueError("a StagePlan needs at least one LayerPlan")
        lp0 = self.layers[0]
        for i, lp in enumerate(self.layers[1:], start=1):
            # full-spec equality, not just kind/layout: the executor
            # dispatches every layer on layer 0's NASpec/SASpec (activation,
            # use_pallas, fuse_epilogue, ...) and inits hidden FP dicts from
            # layer 0's carry, so a differing hidden spec would be silently
            # ignored rather than honoured
            if (lp.na != lp0.na or lp.sa != lp0.sa
                    or lp.residency != lp0.residency
                    or (lp.handoff, lp.carry) != (lp0.handoff, lp0.carry)):
                raise ValueError(
                    "NA/SA/residency specs and the handoff/carry contract "
                    "must be layer-uniform (the host-side index tables are "
                    "built once and the executor dispatches every layer on "
                    f"layer 0's specs); layer {i} declares "
                    f"{(lp.na, lp.sa, lp.residency, lp.handoff, lp.carry)} "
                    f"vs layer 0's "
                    f"{(lp0.na, lp0.sa, lp0.residency, lp0.handoff, lp0.carry)}")

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    # Layer-0 accessors: every pre-multi-layer read site (`plan.na.layout`,
    # `plan.sa.fuse_epilogue`, ...) keeps working, and stays correct for the
    # layer-invariant fields enforced by __post_init__.
    @property
    def fp(self) -> FPSpec:
        return self.layers[0].fp

    @property
    def na(self) -> NASpec:
        return self.layers[0].na

    @property
    def sa(self) -> SASpec:
        return self.layers[0].sa

    @property
    def residency(self) -> Optional[ResidencySpec]:
        return self.layers[0].residency

    @property
    def shards_on_mesh(self) -> bool:
        """CSR gather/scatter cannot shard; every padded layout can."""
        return self.na.layout != "csr"


# Shared batch-sharding rule sets (destination nodes over BATCH, source pools
# replicated — the stage-aware strategy of `stages.HGNN_STAGE_SPECS`).
STACKED_BATCH_SPECS: Tuple[ShardRule, ...] = (
    ("nbr", 3, (None, BATCH, None)),  # HAN [P, N, K]
    ("mask", 3, (None, BATCH, None)),
)
BUCKETED_BATCH_SPECS: Tuple[ShardRule, ...] = (
    ("buckets", 2, (BATCH, None)),  # per-bucket nbr / mask [n_b, K_b]
    ("buckets", 1, (BATCH,)),  # per-bucket row_ids
)
RELATION_BATCH_SPECS: Tuple[ShardRule, ...] = (
    ("rels", 2, (BATCH, None)),  # per-relation nbr / mask [N_d, K]
    ("rels", 1, (BATCH,)),  # per-relation bucket row_ids
)
INSTANCE_BATCH_SPECS: Tuple[ShardRule, ...] = (
    ("instances", 3, (BATCH, None, None)),  # [N, I, L] instance node tables
    ("instances", 2, (BATCH, None)),  # [N, I] instance masks
)
# Partitioned batches: every table under batch["part"] leads with the
# partition dim K, which shards over the BATCH axes (one partition — or a
# contiguous block of partitions — per data-parallel shard).  The 1-d
# leaves (the output inverse permutation) stay replicated.
PARTITION_BATCH_SPECS: Tuple[ShardRule, ...] = (
    ("part", 4, (BATCH, None, None, None)),  # [K, P, n, Kd] / [K, n, I, L]
    ("part", 3, (BATCH, None, None)),  # [K, n, F] feats / [K, n, Kd] rels
    ("part", 2, (BATCH, None)),  # [K, n] masks / [K, H] halo maps
)
