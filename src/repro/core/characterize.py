"""Workload characterization — the paper's contribution as a framework feature.

The paper classifies CUDA kernels into four classes and attributes time/
bandwidth/AI to each (Fig. 3/4, Table 3).  On TPU there are no CUDA kernels;
the equivalent artifact is the compiled per-device HLO module.  This module
walks it with a call-graph-aware cost model:

  * kernel classes:  DM (dot/conv), TB (gather/scatter — graph topology,
    MoE routing, embedding lookups), EW (elementwise/reduce), DR (pure data
    rearrangement: copy/transpose/concat/slice/DUS), COLL (collectives),
    OTHER (custom calls, rng, sort).
  * fusions: FLOPs from the fused computation interior; HBM bytes counted at
    the fusion BOUNDARY (operands+result) — exactly the memory a fused TPU
    kernel moves.
  * while loops (lax.scan over layers / kv chunks): body cost multiplied by
    the ``known_trip_count`` XLA records in backend_config — this is what
    ``compiled.cost_analysis()`` gets wrong (it counts loop bodies once).

Outputs the three roofline terms (v5e constants) per the brief:
    compute    = FLOPs / (chips x 197 TFLOP/s)
    memory     = HBM bytes / (chips x 819 GB/s)
    collective = collective bytes / (chips x 50 GB/s/link)
(all quantities here are per-device, i.e. already divided by chips).
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e hardware constants (per chip) ----
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link (conservative: 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)
DM_OPS = ("dot", "convolution")
TB_OPS = ("gather", "scatter", "dynamic-slice")
DR_OPS = ("copy", "transpose", "reshape", "concatenate", "slice", "pad",
          "dynamic-update-slice", "reverse", "broadcast")
ZERO_OPS = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "iota", "partition-id", "replica-id", "domain",
            "opt-barrier")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[\w\[\]\{\},\. ]+?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\"\{:n ]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the opening paren (operands + attrs)
    result_bytes: int = 0
    result_elems: int = 0

    def operands(self) -> List[str]:
        # operand list terminates at the first unmatched ')'
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return re.findall(r"%([\w\.\-]+)", self.rest[:i])
        return re.findall(r"%([\w\.\-]+)", self.rest)

    def attrs(self) -> str:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return self.rest[i + 1:]
        return ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symtab: Dict[str, Instr] = field(default_factory=dict)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line) and line.rstrip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = Instr(mi.group(1), mi.group(2).strip(), mi.group(3),
                        mi.group(4))
            ins.result_bytes = shape_bytes(ins.type_str)
            ins.result_elems = shape_elems(ins.type_str)
            cur.instrs.append(ins)
            cur.symtab[ins.name] = ins
    return comps, entry


def classify(opcode: str) -> str:
    if opcode in ZERO_OPS:
        return "ZERO"
    if any(opcode.startswith(c) for c in COLLECTIVES):
        return "COLL"
    if opcode in DM_OPS or opcode.startswith("dot"):
        return "DM"
    if opcode in TB_OPS:
        return "TB"
    if opcode in DR_OPS:
        return "DR"
    if opcode in ("fusion", "while", "call", "conditional", "custom-call",
                  "sort", "rng", "rng-bit-generator"):
        return opcode.upper()
    return "EW"  # default: elementwise / reduce / compare / convert ...


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 x prod(result) x prod(lhs contracting dims)."""
    ops = ins.operands()
    k = 1
    m = _CONTRACT_RE.search(ins.attrs())
    if m and ops:
        lhs = comp.symtab.get(ops[0])
        if lhs is not None:
            dims_m = _SHAPE_RE.findall(lhs.type_str)
            if dims_m:
                dims = [int(d) for d in dims_m[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * ins.result_elems * k


class CostWalker:
    """Accumulates per-class flops / hbm bytes / collective bytes across the
    call graph, multiplying while bodies by known_trip_count."""

    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self._memo: Dict[Tuple[str, bool], Dict] = {}

    def _zero(self) -> Dict:
        return {"flops": defaultdict(float), "hbm": defaultdict(float),
                "coll": 0.0, "coll_ops": defaultdict(float),
                "count": defaultdict(int)}

    def _merge(self, a: Dict, b: Dict, mult: float = 1.0):
        for k, v in b["flops"].items():
            a["flops"][k] += v * mult
        for k, v in b["hbm"].items():
            a["hbm"][k] += v * mult
        a["coll"] += b["coll"] * mult
        for k, v in b.get("coll_ops", {}).items():
            a["coll_ops"][k] += v * mult
        for k, v in b["count"].items():
            a["count"][k] += v * int(mult)

    def _called(self, ins: Instr) -> List[str]:
        """Computations executed by this op (while -> body only; the
        condition is O(1) bookkeeping)."""
        attrs = ins.attrs()
        out = []
        for rex in (_CALLS_RE, _BODY_RE, _TO_APPLY_RE):
            m = rex.search(attrs)
            if m and m.group(1) in self.comps:
                out.append(m.group(1))
        m = _BRANCH_RE.search(attrs)
        if m:
            for name in m.group(1).split(","):
                name = name.strip().lstrip("%")
                if name in self.comps:
                    out.append(name)
        return out

    def interior_flops(self, cname: str) -> Dict:
        """FLOPs (by class) of a fused computation's interior (no bytes)."""
        key = (cname, True)
        if key in self._memo:
            return self._memo[key]
        acc = self._zero()
        comp = self.comps[cname]
        for ins in comp.instrs:
            cls = classify(ins.opcode)
            if cls == "ZERO":
                continue
            if cls == "DM":
                acc["flops"]["DM"] += _dot_flops(ins, comp)
            elif cls in ("EW",):
                acc["flops"]["EW"] += ins.result_elems
            elif cls == "TB":
                acc["flops"]["TB"] += ins.result_elems
            elif cls in ("FUSION", "CALL", "WHILE", "CONDITIONAL"):
                for sub in self._called(ins):
                    self._merge(acc, self.interior_flops(sub))
            acc["count"][cls if cls in ("DM", "TB", "EW", "DR") else "OTHER"] += 1
        self._memo[key] = acc
        return acc

    def fusion_class(self, cname: str) -> str:
        f = self.interior_flops(cname)
        if f["flops"]["DM"] > 0:
            return "DM"
        if f["flops"]["TB"] > 0 or f["count"]["TB"] > 0:
            return "TB"
        if f["flops"]["EW"] > 0:
            return "EW"
        return "DR"

    def walk(self, cname: str) -> Dict:
        """Full cost of a computation executed once (top-level semantics)."""
        key = (cname, False)
        if key in self._memo:
            return self._memo[key]
        acc = self._zero()
        comp = self.comps[cname]
        for ins in comp.instrs:
            cls = classify(ins.opcode)
            if cls == "ZERO":
                continue
            if cls == "COLL":
                if ins.opcode.endswith("-done"):
                    continue
                acc["coll"] += ins.result_bytes
                base = ins.opcode.replace("-start", "")
                acc["coll_ops"][base] += ins.result_bytes
                acc["count"]["COLL"] += 1
                continue
            if cls == "FUSION":
                fclass = "EW"
                for sub in self._called(ins):
                    fint = self.interior_flops(sub)
                    self._merge(acc, {"flops": fint["flops"],
                                      "hbm": {}, "coll": 0.0, "count": {}})
                    fclass = self.fusion_class(sub)
                op_bytes = sum(
                    comp.symtab[o].result_bytes for o in ins.operands()
                    if o in comp.symtab)
                acc["hbm"][fclass] += op_bytes + ins.result_bytes
                acc["count"][fclass] += 1
                continue
            if cls == "WHILE":
                trip = 1
                m = _TRIP_RE.search(ins.attrs())
                if m:
                    trip = int(m.group(1))
                for sub in self._called(ins):
                    self._merge(acc, self.walk(sub), mult=trip)
                continue
            if cls in ("CALL", "CONDITIONAL"):
                for sub in self._called(ins):
                    self._merge(acc, self.walk(sub))
                continue
            # plain (unfused) op at top level
            op_bytes = sum(comp.symtab[o].result_bytes for o in ins.operands()
                           if o in comp.symtab)
            bytes_moved = op_bytes + ins.result_bytes
            if cls == "DM":
                acc["flops"]["DM"] += _dot_flops(ins, comp)
                acc["hbm"]["DM"] += bytes_moved
            elif cls == "TB":
                acc["flops"]["TB"] += ins.result_elems
                acc["hbm"]["TB"] += bytes_moved
            elif cls == "DR":
                acc["hbm"]["DR"] += bytes_moved
            elif cls in ("CUSTOM-CALL", "SORT", "RNG", "RNG-BIT-GENERATOR"):
                acc["hbm"]["OTHER"] += bytes_moved
            else:
                acc["flops"]["EW"] += ins.result_elems
                acc["hbm"]["EW"] += bytes_moved
            acc["count"][cls if cls in ("DM", "TB", "EW", "DR") else "OTHER"] += 1
        self._memo[key] = acc
        return acc


def analyze_hlo_text(text: str) -> Dict:
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    walker = CostWalker(comps)
    acc = walker.walk(entry)
    flops = dict(acc["flops"])
    hbm = dict(acc["hbm"])
    return {
        "flops_by_class": {k: float(v) for k, v in flops.items()},
        "hbm_bytes_by_class": {k: float(v) for k, v in hbm.items()},
        "collective_bytes": float(acc["coll"]),
        "collective_bytes_by_op": {k: float(v) for k, v in acc["coll_ops"].items()},
        "op_counts": dict(acc["count"]),
        "total_flops": float(sum(flops.values())),
        "total_hbm_bytes": float(sum(hbm.values())),
    }


# ---------------------------------------------------------------------------
# partitioned-execution traffic record
# ---------------------------------------------------------------------------


def partition_traffic(part: Dict, h_own: Dict, layers: int = 1) -> Dict:
    """Halo-exchange / edge-cut record for the partitioned execution mode.

    ``part`` is the device batch's partition table (``repro.dist.partition``:
    ``halo_mask`` per type + host-side ``meta`` counters); ``h_own`` the
    per-type ``[K, n, ...]`` feature shards entering the ``gather_halo``
    stage, whose trailing dims price a halo row in bytes.  This is the
    paper-facing view of the new communication stage — the bytes that cross
    partitions because an edge was cut — independent of how the exchange is
    lowered (shard_map all-gather vs GSPMD resharding).

    ``layers``: an L-layer stack re-runs the exchange once per layer on the
    *updated* features (the halo maps are graph-invariant and every layer's
    tables are hidden-width), so the total exchanged traffic is the
    per-exchange volume × L — reported as ``halo_bytes_total`` /
    ``halo_rows_total`` next to the per-exchange figures.
    """
    import numpy as np

    halo_rows = 0.0
    halo_bytes = 0.0
    for t, m in part["halo_mask"].items():
        rows = float(np.asarray(m).sum())
        h = h_own[t]
        row_bytes = 1.0
        for d in h.shape[2:]:
            row_bytes *= d
        row_bytes *= h.dtype.itemsize
        halo_rows += rows
        halo_bytes += rows * row_bytes
    meta = part["meta"]
    cut = int(meta["cut_edges"])
    total = int(meta["edges_total"])
    return {
        "k": int(meta["k"]),
        "halo_rows": halo_rows,
        "halo_bytes": halo_bytes,
        "cut_edges": cut,
        "edges_total": total,
        "cut_ratio": cut / max(total, 1),
        "layers": int(layers),
        "halo_rows_total": halo_rows * layers,
        "halo_bytes_total": halo_bytes * layers,
    }


# ---------------------------------------------------------------------------
# request-path sampled-serving traffic record
# ---------------------------------------------------------------------------


def sample_traffic(meta: Dict) -> Dict:
    """SAMPLE-stage record for request-path serving.

    ``meta`` is :class:`repro.serve.sampler.SampledBatch`'s host-side batch
    metadata.  The record is fully deterministic given (graph, seed,
    targets, fan-out) — the quantities the serving bench *gates* — and it is
    the paper taxonomy's Subgraph Build stage realized as the per-request
    neighbor-sampling gather: the frontier feature rows that must be
    fetched beyond the targets themselves (``frontier_bytes``) plus the
    relabeled index tables shipped to the device (``index_bytes``).
    """
    return {
        "rung": list(meta["rung"]),
        "rung_index": int(meta["rung_index"]),
        "n_targets": int(meta["n_targets"]),
        "frontier_rows": int(meta["frontier_rows"]),
        "frontier_bytes": float(meta["frontier_bytes"]),
        "index_bytes": float(meta["index_bytes"]),
        "truncated_rows": int(meta["truncated_rows"]),
        "fanout": int(meta["fanout"]),
    }


def residency_record(counters: Dict, row_bytes: int, layers: int = 1) -> Dict:
    """Hot-feature residency record (``repro.core.residency``).

    ``counters`` are the deterministic hit/miss counters attached to a
    prepared batch (single-device: hot references in the remapped NA index
    tables; partitioned: hot entries in the halo tables) — replayable
    exactly from (graph, seed, plan), which is what the residency bench
    gates at exact equality.  ``row_bytes`` prices one gathered feature row
    (the hidden width — NA gathers projected tables); ``layers`` is the
    number of cached stages in the L-layer stack.  The hot set is
    layer-invariant, so every layer saves ``hits × row_bytes`` of HBM
    gather traffic while the cache fill (``cache_rows × row_bytes``) is
    paid once — HiHGNN-style inter-layer reuse.
    """
    hits = int(counters["hits"])
    misses = int(counters["misses"])
    rows = int(counters["rows"])
    cache_rows = int(counters["cache_rows"])
    fill = cache_rows * int(row_bytes)
    per_layer = hits * int(row_bytes)
    return {
        "cache_rows": cache_rows,
        "hits": hits,
        "misses": misses,
        "rows": rows,
        "hit_rate": hits / max(rows, 1),
        "row_bytes": int(row_bytes),
        "layers": int(layers),
        "fill_bytes": fill,
        "bytes_saved_per_layer": per_layer,
        "bytes_saved_total": per_layer * int(layers) - fill,
    }


def resilience_record(stats: Dict) -> Dict:
    """Resilience counters record for request-path serving.

    ``stats`` is :meth:`repro.serve.engine.HGNNServeEngine.stats`'s return
    value.  Normalizes the nested resilience counters into the flat
    deterministic record the chaos bench and the characterization handbook
    report: per-status request counts, retry/failure totals, the
    degradation trajectory (transitions + peak level — both strictly inside
    the warmed ladder, so ``recompiles`` belongs in the same record), and
    the partition-failover outcome.  Every field replays a seeded fault
    schedule exactly; none is timing-dependent.
    """
    rs = stats.get("resilience", {})
    return {
        "ok_requests": int(rs.get("ok_requests", 0)),
        "partial_requests": int(rs.get("partial_requests", 0)),
        "failed_requests": int(rs.get("failed_requests", 0)),
        "rejected": int(rs.get("rejected", 0)),
        "shed": int(rs.get("shed", 0)),
        "deduped_rows": int(rs.get("deduped_rows", 0)),
        "retries": int(rs.get("retries", 0)),
        "failed_steps": int(rs.get("failed_steps", 0)),
        "deadline_expired": int(rs.get("deadline_expired", 0)),
        "degrade_transitions": int(rs.get("degrade_transitions", 0)),
        "recover_transitions": int(rs.get("recover_transitions", 0)),
        "max_degrade_level": int(rs.get("max_degrade_level", 0)),
        "partition_failovers": int(rs.get("partition_failovers", 0)),
        "lost_partitions": list(rs.get("lost_partitions", [])),
        "steps": int(stats.get("steps", 0)),
        "recompiles": stats.get("compiles_after_warmup"),
    }


# ---------------------------------------------------------------------------
# async stage-graph overlap accounting
# ---------------------------------------------------------------------------


def overlap_accounting(edges: Dict[str, Tuple[str, ...]],
                       walls_us: Dict[str, float]) -> Dict:
    """Critical-path accounting over the plan-derived stage DAG.

    ``edges`` is :meth:`repro.core.pipeline.StageGraphExecutor.
    schedule_edges` (stage → its dependencies, topological order);
    ``walls_us`` the measured per-stage walls.  The *serial sum* is the
    blocking schedule's lower bound (every stage waits for the previous
    one); the *critical path* is the overlapped schedule's — the longest
    dependency chain when independent stages run concurrently.  Their gap
    is the overlap saving; per-stage **exposure** is how much of the
    critical path a stage is actually responsible for (critical path minus
    the critical path with that stage's wall zeroed) — a fully-hidden
    stage (e.g. a halo exchange shorter than the owned-rows NA it overlaps)
    exposes ~0 even with a large wall.
    """
    finish: Dict[str, float] = {}
    for n in edges:  # topological by construction
        finish[n] = (max((finish[d] for d in edges[n]), default=0.0)
                     + walls_us.get(n, 0.0))
    crit = max(finish.values(), default=0.0)

    def _crit_without(skip: str) -> float:
        f: Dict[str, float] = {}
        for n in edges:
            w = 0.0 if n == skip else walls_us.get(n, 0.0)
            f[n] = max((f[d] for d in edges[n]), default=0.0) + w
        return max(f.values(), default=0.0)

    serial = float(sum(walls_us.get(n, 0.0) for n in edges))
    return {
        "serial_sum_us": serial,
        "critical_path_us": float(crit),
        "overlap_saved_us": float(serial - crit),
        "exposure_us": {n: float(crit - _crit_without(n)) for n in edges},
    }


# ---------------------------------------------------------------------------
# model-level analytics + roofline
# ---------------------------------------------------------------------------


def analytic_param_counts(cfg) -> Tuple[float, float]:
    """(total params, active params) from the config (no instantiation)."""
    import jax

    if cfg.family == "encdec":
        from repro.nn.encdec import init_encdec_params

        tree = jax.eval_shape(lambda: init_encdec_params(jax.random.key(0), cfg))
    else:
        from repro.nn.transformer import init_lm_params

        tree = jax.eval_shape(lambda: init_lm_params(jax.random.key(0), cfg))
    total = 0.0
    expert = 0.0

    def visit(path, leaf):
        nonlocal total, expert
        n = float(math.prod(leaf.shape))
        total += n
        names = [str(p.key) for p in path
                 if isinstance(p, __import__("jax").tree_util.DictKey)]
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down"):
            expert += n

    import jax.tree_util as jtu

    jtu.tree_map_with_path(visit, tree)
    active = total - expert
    if cfg.moe is not None and expert > 0:
        active += expert * cfg.moe.top_k / cfg.moe.n_experts
    return total, active


def model_flops(cfg, shape, n_total: float, n_active: float) -> float:
    """The brief's MODEL_FLOPS: 6·N·D train (N_active for MoE), 2·N·D fwd."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token / sequence


def roofline(per_device: Dict, n_chips: int, model_fl: float) -> Dict:
    t_c = per_device["total_flops"] / PEAK_FLOPS
    t_m = per_device["total_hbm_bytes"] / HBM_BW
    t_l = per_device["collective_bytes"] / LINK_BW
    bound = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))[1]
    t_step = max(t_c, t_m, t_l)
    model_fl_dev = model_fl / n_chips
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_l,
        "bound": bound,
        "step_time_s": t_step,
        "model_flops_total": model_fl,
        "model_flops_per_device": model_fl_dev,
        "useful_flops_ratio": model_fl_dev / per_device["total_flops"]
        if per_device["total_flops"] else 0.0,
        "mfu_proxy": model_fl_dev / (t_step * PEAK_FLOPS) if t_step else 0.0,
        "roofline_fraction": (model_fl_dev / PEAK_FLOPS) / t_step if t_step else 0.0,
    }


def analyze_compiled(compiled, cfg=None, shape=None, n_chips: int = 1) -> Dict:
    """Full report for a compiled (post-SPMD, per-device) executable."""
    rep = analyze_hlo_text(compiled.as_text())
    out = {"hlo": rep}
    if cfg is not None and shape is not None:
        n_total, n_active = analytic_param_counts(cfg)
        mf = model_flops(cfg, shape, n_total, n_active)
        out["params_total"] = n_total
        out["params_active"] = n_active
        out["roofline"] = roofline(rep, n_chips, mf)
    else:
        out["roofline"] = roofline(rep, n_chips, 0.0)
    return out


def analyze_jitted(fn, *args, cfg=None, shape=None, n_chips: int = 1, **jit_kw):
    """Convenience: jit+lower+compile then analyze (used by HGNN benches)."""
    import jax

    compiled = jax.jit(fn, **jit_kw).lower(*args).compile()
    return analyze_compiled(compiled, cfg=cfg, shape=shape, n_chips=n_chips)
