"""The paper's HGNN execution stages as composable JAX modules.

Stage 2 — Feature Projection (FP):   type-specific dense matmul (DM-Type).
Stage 3 — Neighbor Aggregation (NA): graph-topology gather + reduce (TB-Type)
                                     with element-wise attention math (EW-Type).
Stage 4 — Semantic Aggregation (SA): lives in :mod:`repro.core.semantics`.

Two NA execution paths:

* ``*_csr``  — DGL-faithful baseline: flat gather + ``segment_sum`` /
  ``segment_max`` over edge lists.  Lowers to gather/scatter HLO — the
  TB-Type irregular pattern the paper profiles (SpMMCsr / SDDMMCoo).
* ``*_padded`` — TPU-adapted optimized path: degree-capped dense ``[N, K]``
  neighbor tiles; the reduction tree becomes a masked dense reduction that
  feeds the MXU/VPU and tiles into VMEM (see kernels/segment_spmm.py for the
  Pallas version).

All functions are pure and jit-able; parameters are plain dict pytrees.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import BATCH, MODEL, shard

# Stage-aware partitioning strategy (the paper's taxonomy drives the specs):
#   FP  (DM-Type dense matmul)      -> hidden dim over MODEL, nodes over BATCH
#   NA  (TB-Type irregular gather)  -> destination nodes over BATCH; the
#                                      source pool replicated (arbitrary
#                                      gathers cannot stay sharded)
#   SA  (EW-Type elementwise+reduce)-> rides the NA layout, nodes over BATCH
# Every entry is a logical per-dim spec resolved by repro.dist.resolve_spec.
HGNN_STAGE_SPECS: Dict[str, Tuple] = {
    "fp_weight": (None, MODEL),          # [F_t, hidden]
    "fp_out": (BATCH, MODEL),            # [N_t, hidden]
    "na_dst": (BATCH, None, None),       # [N, H, Dh]
    "na_src": (None, None, None),        # [M, H, Dh] replicated gather pool
    "na_nbr": (BATCH, None),             # [N, K]  (also [N, I] instance masks)
    "na_out": (BATCH, None, None),       # [N, H, Dh]
    "na_inst_nodes": (BATCH, None, None),  # [N, I, L] MAGNN instance tables
    "na_flat_out": (BATCH, None),        # [N, D] flattened NA output
    "sa_stacked": (None, BATCH, None),   # [P, N, D]
}


# ---------------------------------------------------------------------------
# Stage 2: Feature Projection
# ---------------------------------------------------------------------------


def init_feature_projection(
    rng: jax.Array, feat_dims: Dict[str, int], hidden: int
) -> Dict[str, jax.Array]:
    keys = jax.random.split(rng, len(feat_dims))
    return {
        t: jax.random.normal(k, (d, hidden), jnp.float32) / np.sqrt(d)
        for k, (t, d) in zip(keys, sorted(feat_dims.items()))
    }


def feature_projection(
    params: Dict[str, jax.Array], feats: Dict[str, jax.Array]
) -> Dict[str, jax.Array]:
    """Project per-type raw features into the shared latent space (DM-Type)."""
    return {t: feats[t] @ params[t] for t in feats}


def feature_projection_sharded(
    params: Dict[str, jax.Array], feats: Dict[str, jax.Array]
) -> Dict[str, jax.Array]:
    """FP with the stage-aware partitioning: the dense DM-Type matmul is the
    one HGNN stage that shards like an LM layer — weights column-sharded over
    'model', per-type node rows over the batch axes.  No-op off-mesh."""
    return {
        t: shard(feats[t] @ shard(params[t], *HGNN_STAGE_SPECS["fp_weight"]),
                 *HGNN_STAGE_SPECS["fp_out"])
        for t in feats
    }


# ---------------------------------------------------------------------------
# Stage 3: Neighbor Aggregation
# ---------------------------------------------------------------------------


def init_gat(rng: jax.Array, n_heads: int, head_dim: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(rng)
    s = 1.0 / np.sqrt(head_dim)
    return {
        "a_dst": jax.random.normal(k1, (n_heads, head_dim), jnp.float32) * s,
        "a_src": jax.random.normal(k2, (n_heads, head_dim), jnp.float32) * s,
    }


def _leaky_relu(x, slope=0.2):
    return jnp.where(x >= 0, x, slope * x)


def gat_aggregate_padded(
    p: Dict[str, jax.Array],
    h_dst: jax.Array,  # [N, H, Dh] projected features of target nodes
    h_src: Optional[jax.Array],  # [M, H, Dh] projected neighbor pool
    nbr: jax.Array,  # [N, K] int32 (may be None when hn/e_nbr pre-gathered)
    mask: jax.Array,  # [N, K] float
    hn: Optional[jax.Array] = None,  # [N, K, H, Dh] pre-gathered neighbors
    e_nbr: Optional[jax.Array] = None,  # [N, K, H] pre-gathered src scores
) -> jax.Array:
    """GAT neighbor aggregation over a padded subgraph. Returns [N, H, Dh].

    ``hn`` / ``e_nbr`` let the async schedule's split stages supply the two
    TB gathers pre-merged (owned rows gathered while the halo exchange was
    still in flight, where-selected against the halo rows afterwards) —
    pure row selections, so the attention math below is bitwise identical
    to the gather-from-``h_src`` default.
    """
    e_dst = (h_dst * p["a_dst"]).sum(-1)  # [N, H]   EW
    if hn is None:
        hn = h_src[nbr]  # [N, K, H, Dh]  TB gather
    if e_nbr is None:
        e_nbr = (h_src * p["a_src"]).sum(-1)[nbr]  # [M, H] EW -> [N, K, H]
    e = _leaky_relu(e_dst[:, None, :] + e_nbr)  # [N, K, H]
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    e = e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True))
    w = jnp.exp(e) * mask[..., None]
    alpha = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)  # [N, K, H]
    out = jnp.einsum("nkh,nkhd->nhd", alpha, hn)  # reduction tree
    return out


def gat_aggregate_csr(
    p: Dict[str, jax.Array],
    h_dst: jax.Array,  # [N, H, Dh]
    h_src: jax.Array,  # [M, H, Dh]
    seg: jax.Array,  # [E] int32 destination (segment) id per edge
    idx: jax.Array,  # [E] int32 source id per edge
    n_nodes: int,
) -> jax.Array:
    """DGL-faithful GAT: SDDMM (edge scores) + segment-softmax + SpMM."""
    e_dst = (h_dst * p["a_dst"]).sum(-1)  # [N, H]
    e_src = (h_src * p["a_src"]).sum(-1)  # [M, H]
    e = _leaky_relu(e_dst[seg] + e_src[idx])  # [E, H]  SDDMM-like
    m = jax.ops.segment_max(e, seg, num_segments=n_nodes)  # scatter-max
    w = jnp.exp(e - jax.lax.stop_gradient(m[seg]))
    denom = jax.ops.segment_sum(w, seg, num_segments=n_nodes)
    alpha = w / jnp.maximum(denom[seg], 1e-9)  # [E, H]
    msg = h_src[idx] * alpha[..., None]  # [E, H, Dh]
    return jax.ops.segment_sum(msg, seg, num_segments=n_nodes)  # SpMM


def gat_aggregate_padded_sharded(
    p: Dict[str, jax.Array],
    h_dst: jax.Array,
    h_src: jax.Array,
    nbr: jax.Array,
    mask: jax.Array,
) -> jax.Array:
    """Padded NA with the stage-aware partitioning: destination nodes (and
    their neighbor lists) shard over BATCH; the source pool is replicated so
    the TB-Type gather stays local.  No-op off-mesh."""
    h_dst = shard(h_dst, *HGNN_STAGE_SPECS["na_dst"])
    h_src = shard(h_src, *HGNN_STAGE_SPECS["na_src"])
    nbr = shard(nbr, *HGNN_STAGE_SPECS["na_nbr"])
    mask = shard(mask, *HGNN_STAGE_SPECS["na_nbr"])
    out = gat_aggregate_padded(p, h_dst, h_src, nbr, mask)
    return shard(out, *HGNN_STAGE_SPECS["na_out"])


def gat_aggregate_padded_stacked(
    p_stacked: Dict[str, jax.Array],
    h: jax.Array,
    nbr: jax.Array,  # [P, N, K] stacked per-metapath subgraphs
    mask: jax.Array,
    agg_fn: Optional[Callable] = None,
    stacked_fn: Optional[Callable] = None,
    h_src: Optional[jax.Array] = None,
) -> jax.Array:
    """Inter-subgraph-parallel NA over stacked padded subgraphs with the
    stage-aware sharding applied at the stacked level (constraints sit
    outside the vmap): destination nodes over BATCH, source pool replicated,
    metapath dim unsharded.  ``agg_fn`` swaps the per-subgraph body (vmapped
    over the stack); ``stacked_fn`` consumes the whole ``[P, N, K]`` stack in
    one call — the fused Pallas GAT-NA kernel path, ONE launch per stack.
    ``h_src`` swaps the gather pool (default: the destination table itself;
    the residency arm passes the cache-extended pool)."""
    h_src = shard(h if h_src is None else h_src,
                  *HGNN_STAGE_SPECS["na_src"])
    nbr = shard(nbr, None, *HGNN_STAGE_SPECS["na_nbr"])
    mask = shard(mask, None, *HGNN_STAGE_SPECS["na_nbr"])
    if stacked_fn is not None:
        z = stacked_fn(p_stacked, h, h_src, nbr, mask)
    else:
        base = agg_fn or gat_aggregate_padded
        z = jax.vmap(lambda pp, nn, mm: base(pp, h, h_src, nn, mm),
                     in_axes=(0, 0, 0))(p_stacked, nbr, mask)
    return shard(z, None, *HGNN_STAGE_SPECS["na_out"])


def gat_aggregate_bucketed(
    p: Dict[str, jax.Array],
    h_dst: jax.Array,  # [N, H, Dh]
    h_src: jax.Array,  # [M, H, Dh]
    buckets,  # sequence of (row_ids [n_b], nbr [n_b, K_b], mask) device arrays
    agg_fn: Optional[Callable] = None,
) -> jax.Array:
    """GAT NA over a degree-bucketed layout (``core.metapath.bucket_padded``).

    Each bucket runs the padded aggregation at its own degree cap ``K_b``
    (2-3 dense launches instead of one ``K=max_degree`` launch whose
    reduction tree is mostly padding); outputs scatter back to node order
    through ``row_ids``.  ``agg_fn`` swaps in the fused Pallas kernel."""
    base = agg_fn or gat_aggregate_padded
    h_src = shard(h_src, *HGNN_STAGE_SPECS["na_src"])
    out = jnp.zeros(h_dst.shape, h_dst.dtype)
    for row_ids, nbr, mask in buckets:
        z = base(p, jnp.take(h_dst, row_ids, axis=0), h_src,
                 shard(nbr, *HGNN_STAGE_SPECS["na_nbr"]),
                 shard(mask, *HGNN_STAGE_SPECS["na_nbr"]))
        out = out.at[row_ids].set(z.astype(out.dtype))
    return shard(out, *HGNN_STAGE_SPECS["na_out"])


def mean_aggregate_padded(
    h_src: Optional[jax.Array], nbr: jax.Array, mask: jax.Array,
    hn: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean NA (RGCN). h_src [M, D] -> [N, D].  ``hn`` supplies the gather
    pre-merged (async schedule's own/halo split) — same rows, same sum."""
    if hn is None:
        hn = h_src[nbr]  # [N, K, D]
    s = (hn * mask[..., None]).sum(axis=1)
    d = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return s / d


def gather_own(own: jax.Array, idx: jax.Array) -> jax.Array:
    """Owned-side half of a split own/halo gather (see :func:`gather_merge`).

    Indices pointing past the owned table clip to its last row; the merge
    discards those lanes in favour of the halo side, so the clip value
    never reaches the output.  Depends only on the owned table — the async
    schedule dispatches it while the halo exchange is still in flight.
    """
    return own[jnp.clip(idx, 0, own.shape[0] - 1)]


def gather_merge(
    own_sel: jax.Array,  # gather_own(own, idx)
    halo: jax.Array,  # [h_max, ...] exchanged halo rows (h_max may be 0)
    idx: jax.Array,  # indices into the virtual concat([own, halo]) table
    n_own: int,
) -> jax.Array:
    """Merge the split gather: ``concat([own, halo])[idx]`` as a where-select
    of the two clipped row selections.  Pure row copies — bitwise equal to
    gathering from the materialized concatenation."""
    if halo.shape[0] == 0:
        return own_sel
    halo_sel = halo[jnp.clip(idx - n_own, 0, halo.shape[0] - 1)]
    cond = (idx < n_own).reshape(idx.shape + (1,) * (halo.ndim - 1))
    return jnp.where(cond, own_sel, halo_sel)


def mean_aggregate_padded_sharded(
    h_src: jax.Array, nbr: jax.Array, mask: jax.Array,
    agg_fn: Optional[Callable] = None,
) -> jax.Array:
    """Mean NA (RGCN) with stage-aware sharding: destinations over BATCH,
    source pool replicated (``HGNN_STAGE_SPECS["na_src"]``; spec entries past
    ``h_src.ndim`` are ignored by ``resolve_spec``).  No-op off-mesh.
    ``agg_fn`` swaps in the Pallas ``segment_spmm`` kernel."""
    h_src = shard(h_src, *HGNN_STAGE_SPECS["na_src"])
    nbr = shard(nbr, *HGNN_STAGE_SPECS["na_nbr"])
    mask = shard(mask, *HGNN_STAGE_SPECS["na_nbr"])
    base = agg_fn or mean_aggregate_padded
    return shard(base(h_src, nbr, mask), BATCH, None)


def mean_aggregate_bucketed(
    h_src: jax.Array,  # [M, D]
    buckets,  # sequence of (row_ids [n_b], nbr [n_b, K_b], mask) device arrays
    n_rows: int,
    agg_fn: Optional[Callable] = None,
) -> jax.Array:
    """Mean NA over a degree-bucketed layout — `gat_aggregate_bucketed`'s
    dispatch with ``agg_fn=mean`` for RGCN's per-relation tables.

    Each bucket runs the padded mean at its own degree cap ``K_b`` and
    scatters back through ``row_ids``; ``agg_fn`` swaps in the Pallas
    ``segment_spmm`` kernel.  Stage-aware sharding as in the padded path:
    destinations over BATCH, source pool replicated (no-op off-mesh)."""
    base = agg_fn or mean_aggregate_padded
    h_src = shard(h_src, *HGNN_STAGE_SPECS["na_src"])
    out = jnp.zeros((n_rows, h_src.shape[-1]), h_src.dtype)
    for row_ids, nbr, mask in buckets:
        z = base(h_src,
                 shard(nbr, *HGNN_STAGE_SPECS["na_nbr"]),
                 shard(mask, *HGNN_STAGE_SPECS["na_nbr"]))
        out = out.at[row_ids].set(z.astype(out.dtype))
    return shard(out, *HGNN_STAGE_SPECS["na_flat_out"])


def mean_aggregate_csr(
    h_src: jax.Array, seg: jax.Array, idx: jax.Array, n_nodes: int
) -> jax.Array:
    s = jax.ops.segment_sum(h_src[idx], seg, num_segments=n_nodes)
    d = jax.ops.segment_sum(jnp.ones_like(seg, h_src.dtype), seg, num_segments=n_nodes)
    return s / jnp.maximum(d[:, None], 1.0)


def csr_to_edges(indptr: np.ndarray, indices: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side: CSR -> (segment_ids, indices) flat edge list."""
    degrees = np.diff(indptr)
    seg = np.repeat(np.arange(len(degrees), dtype=np.int32), degrees)
    return seg, indices.astype(np.int32)


# ---------------------------------------------------------------------------
# Instance aggregation (MAGNN intra-metapath)
# ---------------------------------------------------------------------------


def init_instance_attention(rng: jax.Array, n_heads: int, head_dim: int):
    return init_gat(rng, n_heads, head_dim)


def rotate_encoder(h_path: jax.Array) -> jax.Array:
    """MAGNN's relational rotation (RotatE-style) instance encoder.

    ``h_path``: [N, I, L, H, Dh] projected features along each instance.
    Treats feature pairs as complex numbers and composes positions by
    rotation, then averages. Falls back to mean when L == 1.
    """
    n, i, l, h, dh = h_path.shape
    re, im = h_path[..., 0::2], h_path[..., 1::2]
    # cumulative rotation along the path
    acc_re, acc_im = re[:, :, 0], im[:, :, 0]
    out_re, out_im = acc_re, acc_im
    for pos in range(1, l):
        r, s = re[:, :, pos], im[:, :, pos]
        acc_re, acc_im = acc_re * r - acc_im * s, acc_re * s + acc_im * r
        out_re = out_re + acc_re
        out_im = out_im + acc_im
    out = jnp.stack([out_re / l, out_im / l], axis=-1).reshape(n, i, h, dh)
    return out


def instance_aggregate(
    p: Dict[str, jax.Array],
    h_tgt: jax.Array,  # [N, H, Dh]
    enc: jax.Array,  # [N, I, H, Dh] encoded instances
    mask: jax.Array,  # [N, I]
) -> jax.Array:
    """Attention over metapath instances per target node -> [N, H, Dh]."""
    e_t = (h_tgt * p["a_dst"]).sum(-1)  # [N, H]
    e_i = (enc * p["a_src"]).sum(-1)  # [N, I, H]
    e = _leaky_relu(e_t[:, None, :] + e_i)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    e = e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True))
    w = jnp.exp(e) * mask[..., None]
    alpha = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    return jnp.einsum("nih,nihd->nhd", alpha, enc)
