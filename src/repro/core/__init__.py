from repro.core.hgraph import HeteroGraph, metapath_adjacency, sparsity  # noqa: F401
from repro.core import metapath, semantics, stages  # noqa: F401
