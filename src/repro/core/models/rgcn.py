"""R-GCN — Relational GCN (Schlichtkrull et al., ESWC'18).

Stages (paper Table 1): Relation Walk | per-relation Linear | Mean | Sum.
The early-stage HGNN: Semantic Aggregation is a plain sum (Reduce kernel,
memory-bound only — §4.4 of the paper).

Updates every node type: h'_d = act(W_0 h_d + Σ_{r: s->d} mean_{N_r}(h_s) W_r).

Execution is declared as a :class:`StagePlan`: NA layout ``csr`` (baseline),
``padded`` (``cfg.fused``), or ``bucketed`` (``cfg.degree_buckets > 1`` —
the per-relation tables ride the same degree-bucket dispatch as HAN, with
``agg_fn=mean``).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import stages
from repro.core.hgraph import HeteroGraph
from repro.core.pipeline import PlannedModel
from repro.core.plan import (PARTITION_BATCH_SPECS, RELATION_BATCH_SPECS,
                             FPSpec, HeadSpec, LayerPlan, NASpec,
                             PartitionSpec, ResidencySpec, SampleSpec, SASpec,
                             ScheduleSpec, StagePlan, default_sample_ladder)
from repro.data.synthetic import DATASET_TARGET


class RGCN(PlannedModel):
    def __init__(self, cfg: HGNNConfig):
        super().__init__(cfg)
        self.target = DATASET_TARGET[cfg.dataset]
        self.rel_keys: List[Tuple[str, str, str]] = []

    def plan(self) -> StagePlan:
        cfg = self.cfg
        if not cfg.fused:
            layout = "csr"
        elif cfg.degree_buckets > 1:
            layout = "bucketed"
        else:
            layout = "padded"
        part = None
        if cfg.partitions >= 1:
            if layout != "padded":
                raise ValueError(
                    "partitioned RGCN execution needs the padded per-relation "
                    f"layout (fused=True, no degree buckets); got {layout!r}")
            part = PartitionSpec(k=cfg.partitions)
        na = NASpec(kind="mean", layout=layout, use_pallas=cfg.use_pallas)
        sample = None
        if cfg.fanout >= 1:
            # the relation count is graph-side (plan() has no hg); size the
            # auto ladder for a nominal 4 relations — the sampler clamps
            # per-type and counts any truncation
            k = min(cfg.fanout, cfg.max_degree)
            sample = SampleSpec(
                fanout=cfg.fanout,
                ladder=(cfg.sample_ladder or default_sample_ladder(
                    cfg.fanout, 4 * k, cfg.layers)),
                seed=cfg.seed)
        residency = (ResidencySpec(cache_rows=cfg.cache_rows)
                     if cfg.cache_rows >= 1 else None)
        # rel_sum SA updates EVERY node type (handoff="all"); hidden layers
        # need no FP — the per-layer w_rel / w_self matmuls inside NA/SA are
        # the layer's linear transform (h' = relu(W_0 h + sum mean(h_s) W_r))
        return StagePlan(
            model="rgcn",
            target=self.target,
            layers=tuple(
                LayerPlan(
                    fp=(FPSpec(kind="per_type", sharded=True) if l == 0
                        else FPSpec(kind="identity")),
                    na=na, sa=SASpec(kind="rel_sum"), handoff="all",
                    residency=residency)
                for l in range(cfg.layers)),
            head=HeadSpec(kind="select_linear", target=self.target),
            batch_specs=(PARTITION_BATCH_SPECS if part is not None
                         else RELATION_BATCH_SPECS),
            partition=part,
            sample=sample,
            schedule=(ScheduleSpec(depth=cfg.overlap)
                      if cfg.overlap >= 1 else None),
        )

    # ---------------- Stage 1: Relation Walk (host) ----------------
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        self.rel_keys = sorted(hg.relations.keys())
        batch: Dict = {
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "counts": dict(hg.node_counts),
            "feat_dims": {t: hg.feat_dim(t) for t in hg.features},
            "rels": {},
        }
        for key in self.rel_keys:
            s, r, d = key
            # incoming edges to type d from type s
            adj_in = hg.relations[key].T.tocsr()
            if cfg.fused:
                nbr = np.zeros((adj_in.shape[0], cfg.max_degree), np.int32)
                mask = np.zeros((adj_in.shape[0], cfg.max_degree), np.float32)
                indptr, indices = adj_in.indptr, adj_in.indices
                for u in range(adj_in.shape[0]):
                    nbrs = indices[indptr[u] : indptr[u + 1]]
                    if len(nbrs) > cfg.max_degree:
                        nbrs = rng.choice(nbrs, cfg.max_degree, replace=False)
                    nbr[u, : len(nbrs)] = nbrs
                    mask[u, : len(nbrs)] = 1.0
                if cfg.degree_buckets > 1:
                    # degree-bucketed per-relation tables (open ROADMAP item):
                    # same quantile K-caps as HAN, scattered back via row_ids
                    bk = mp.bucket_padded(
                        mp.PaddedSubgraph(nbr, mask, [s, d]),
                        cfg.degree_buckets)
                    batch["rels"][key] = [
                        (jnp.asarray(bk.row_ids[i]), jnp.asarray(bk.nbr[i]),
                         jnp.asarray(bk.mask[i]))
                        for i in range(bk.n_buckets)
                    ]
                else:
                    batch["rels"][key] = (jnp.asarray(nbr), jnp.asarray(mask))
            else:
                seg, idx = stages.csr_to_edges(adj_in.indptr, adj_in.indices)
                batch["rels"][key] = (jnp.asarray(seg), jnp.asarray(idx))
        return self._maybe_partition(batch)
