"""R-GCN — Relational GCN (Schlichtkrull et al., ESWC'18).

Stages (paper Table 1): Relation Walk | per-relation Linear | Mean | Sum.
The early-stage HGNN: Semantic Aggregation is a plain sum (Reduce kernel,
memory-bound only — §4.4 of the paper).

Updates every node type: h'_d = act(W_0 h_d + Σ_{r: s->d} mean_{N_r}(h_s) W_r).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import semantics, stages
from repro.core.hgraph import HeteroGraph
from repro.data.synthetic import DATASET_TARGET


class RGCN:
    def __init__(self, cfg: HGNNConfig):
        self.cfg = cfg
        self.target = DATASET_TARGET[cfg.dataset]
        self.rel_keys: List[Tuple[str, str, str]] = []

    # ---------------- Stage 1: Relation Walk (host) ----------------
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        self.rel_keys = sorted(hg.relations.keys())
        batch: Dict = {
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "counts": dict(hg.node_counts),
            "feat_dims": {t: hg.feat_dim(t) for t in hg.features},
            "rels": {},
        }
        for key in self.rel_keys:
            s, r, d = key
            # incoming edges to type d from type s
            adj_in = hg.relations[key].T.tocsr()
            if cfg.fused:
                import scipy.sparse as sp

                nbr = np.zeros((adj_in.shape[0], cfg.max_degree), np.int32)
                mask = np.zeros((adj_in.shape[0], cfg.max_degree), np.float32)
                indptr, indices = adj_in.indptr, adj_in.indices
                for u in range(adj_in.shape[0]):
                    nbrs = indices[indptr[u] : indptr[u + 1]]
                    if len(nbrs) > cfg.max_degree:
                        nbrs = rng.choice(nbrs, cfg.max_degree, replace=False)
                    nbr[u, : len(nbrs)] = nbrs
                    mask[u, : len(nbrs)] = 1.0
                batch["rels"][key] = (jnp.asarray(nbr), jnp.asarray(mask))
            else:
                seg, idx = stages.csr_to_edges(adj_in.indptr, adj_in.indices)
                batch["rels"][key] = (jnp.asarray(seg), jnp.asarray(idx))
        return batch

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg = self.cfg
        d = cfg.hidden
        k_fp, k_rel, k_self, k_cls = jax.random.split(rng, 4)
        rel_ks = jax.random.split(k_rel, max(len(self.rel_keys), 1))
        self_ks = jax.random.split(k_self, len(batch["counts"]))
        return {
            # per-type input projection (raw dims differ across types)
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            # per-relation transform W_r
            "w_rel": {
                key: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for key, k in zip(self.rel_keys, rel_ks)
            },
            # self-loop W_0 per type
            "w_self": {
                t: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for t, k in zip(sorted(batch["counts"]), self_ks)
            },
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }

    # ---------------- Stage 2: Feature Projection ----------------
    def fp(self, params: Dict, batch: Dict) -> Dict[str, jax.Array]:
        # stage-aware sharded FP (DM-Type): no-op off-mesh
        return stages.feature_projection_sharded(params["fp"], batch["feats"])

    # ---------------- Stage 3: Neighbor Aggregation (mean, per relation) ----
    def na(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        # string keys keep the pytree sortable ("__h__" rides along for the
        # self-loop term in Semantic Aggregation)
        out: Dict = {"__h__": h}
        for key in self.rel_keys:
            s, r, d = key
            a, b = batch["rels"][key]
            if self.cfg.fused:
                agg_fn = None
                if self.cfg.use_pallas:
                    # Pallas segment-SpMM on the TB-Type hot loop; streams
                    # the source table from HBM when it exceeds VMEM.
                    from repro.kernels import ops as kops

                    agg_fn = lambda hs, nn, mm: kops.segment_spmm(
                        hs, nn, mm, mean=True, use_pallas=True)
                # stage-aware sharded NA (no-op off-mesh)
                agg = stages.mean_aggregate_padded_sharded(h[s], a, b,
                                                           agg_fn=agg_fn)
            else:
                agg = stages.mean_aggregate_csr(h[s], a, b, batch["counts"][d])
            out["|".join(key)] = agg @ params["w_rel"][key]
        return out

    # ---------------- Stage 4: Semantic Aggregation (sum across relations) --
    def sa(self, params: Dict, batch: Dict, z) -> Dict[str, jax.Array]:
        h = z["__h__"]
        h_new: Dict[str, jax.Array] = {}
        for t in batch["counts"]:
            acc = None
            for key, v in z.items():
                if key != "__h__" and key.split("|")[2] == t:
                    acc = v if acc is None else acc + v  # Reduce (sum)
            h_self = h[t] @ params["w_self"][t]
            h_new[t] = jax.nn.relu(h_self if acc is None else h_self + acc)
        return h_new

    def head(self, params: Dict, z: Dict[str, jax.Array]) -> jax.Array:
        return z[self.target] @ params["cls"]

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.fp(params, batch)
        z = self.na(params, batch, h)
        return self.head(params, self.sa(params, batch, z))
