"""MAGNN — Metapath Aggregated GNN (Fu et al., WWW'20).

Stages (paper Table 1): Metapath Walk | Linear | GAT | Attention Sum.
Unlike HAN, Neighbor Aggregation operates on metapath *instances*: every
instance is encoded from the projected features of ALL nodes along the path
(relational-rotation encoder), then attention aggregates instances per target.

Instance enumeration is sampled (cap per target node) — full enumeration
explodes through hub nodes (DBLP's 20 venues); see core/metapath.py.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import semantics, stages
from repro.core.hgraph import HeteroGraph
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


class MAGNN:
    def __init__(self, cfg: HGNNConfig):
        self.cfg = cfg
        self.metapaths = DATASET_METAPATHS[cfg.dataset]
        self.target = DATASET_TARGET[cfg.dataset]

    # ---------------- Stage 1: Subgraph Build (host, sampled instances) -----
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        insts = [
            mp.enumerate_instances(hg, p, cfg.max_instances, rng=rng)
            for p in self.metapaths
        ]
        return {
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "feat_dims": {t: hg.feat_dim(t) for t in hg.features},
            "instances": [
                (jnp.asarray(ib.nodes), jnp.asarray(ib.mask), ib.types) for ib in insts
            ],
            "n_nodes": hg.node_counts[self.target],
        }

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg = self.cfg
        d, H = cfg.hidden, cfg.n_heads
        head_dim = d // H
        k_fp, k_att, k_sem, k_cls = jax.random.split(rng, 4)
        att_ks = jax.random.split(k_att, len(self.metapaths))
        return {
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            "att": [stages.init_instance_attention(k, H, head_dim) for k in att_ks],
            "sem": semantics.init_semantic_attention(k_sem, d, cfg.attn_hidden),
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }

    # ---------------- Stage 2: Feature Projection ----------------
    def fp(self, params: Dict, batch: Dict) -> Dict[str, jax.Array]:
        return stages.feature_projection(params["fp"], batch["feats"])

    # ---------------- Stage 3: NA over metapath instances ----------------
    def na(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]) -> List[jax.Array]:
        cfg = self.cfg
        H = cfg.n_heads
        outs: List[jax.Array] = []
        for p_i, (nodes, mask, types) in zip(params["att"], batch["instances"]):
            n, i, l = nodes.shape
            # gather projected features per path position (types known statically)
            h_path = jnp.stack(
                [h[types[j]][nodes[:, :, j]] for j in range(l)], axis=2
            )  # [N, I, L, D]
            h_path = h_path.reshape(n, i, l, H, -1)
            enc = stages.rotate_encoder(h_path)  # [N, I, H, Dh]
            h_tgt = h[self.target].reshape(-1, H, h_path.shape[-1])
            if cfg.use_pallas:
                # Instance attention IS padded GAT NA with the encoded
                # instances as the source pool: node n's instances live at
                # rows [n*I, (n+1)*I) of the flattened table, so the fused
                # kernel covers MAGNN with an arange neighbor grid.
                from repro.kernels import ops as kops

                flat = enc.reshape(n * i, H, enc.shape[-1])
                nbr_inst = jnp.arange(n * i, dtype=jnp.int32).reshape(n, i)
                z = kops.gat_aggregate(p_i, h_tgt, flat, nbr_inst, mask,
                                       use_pallas=True)
            else:
                z = stages.instance_aggregate(p_i, h_tgt, enc, mask)
            outs.append(jax.nn.elu(z).reshape(n, -1))  # [N, D]
        return outs

    # ---------------- Stage 4: Semantic Aggregation ----------------
    def sa(self, params: Dict, batch: Dict, z: List[jax.Array]) -> jax.Array:
        return semantics.semantic_attention_list(params["sem"], z)

    def head(self, params: Dict, z: jax.Array) -> jax.Array:
        return z @ params["cls"]

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.fp(params, batch)
        return self.head(params, self.sa(params, batch, self.na(params, batch, h)))
