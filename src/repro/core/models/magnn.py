"""MAGNN — Metapath Aggregated GNN (Fu et al., WWW'20).

Stages (paper Table 1): Metapath Walk | Linear | GAT | Attention Sum.
Unlike HAN, Neighbor Aggregation operates on metapath *instances*: every
instance is encoded from the projected features of ALL nodes along the path
(relational-rotation encoder), then attention aggregates instances per target.

Instance enumeration is sampled (cap per target node) — full enumeration
explodes through hub nodes (DBLP's 20 venues); see core/metapath.py.

Execution is declared as a :class:`StagePlan` with NA layout ``instances``;
the per-position node types are static and ride the plan (``metapaths``), so
the device batch holds arrays only and the instance tables shard over the
stage-aware destination-node BATCH axes.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core.hgraph import HeteroGraph
from repro.core.pipeline import PlannedModel
from repro.core.plan import (INSTANCE_BATCH_SPECS, PARTITION_BATCH_SPECS,
                             FPSpec, HeadSpec, LayerPlan, NASpec,
                             PartitionSpec, ResidencySpec, SampleSpec, SASpec,
                             ScheduleSpec, StagePlan, default_sample_ladder)
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


class MAGNN(PlannedModel):
    def __init__(self, cfg: HGNNConfig):
        super().__init__(cfg)
        self.metapaths = DATASET_METAPATHS[cfg.dataset]
        self.target = DATASET_TARGET[cfg.dataset]

    def plan(self) -> StagePlan:
        cfg = self.cfg
        part = (PartitionSpec(k=cfg.partitions) if cfg.partitions >= 1
                else None)
        na = NASpec(kind="instance", layout="instances", activation="elu",
                    use_pallas=cfg.use_pallas)
        sa = SASpec(kind="attention", stacked=False)
        # instance gathers touch every metapath position's type, so hidden
        # layers carry the non-target positions forward from this layer's FP
        # (handoff="target+carry") and re-project all of them ([D, D] per
        # type) before the next round of gathers
        carry = tuple(sorted({ty for p in self.metapaths for ty in p}
                             - {self.target}))
        sample = None
        if cfg.fanout >= 1:
            # instances per target are the MAGNN fan-out knob; every kept
            # instance pulls its full node path into the frontier
            k = min(cfg.fanout, cfg.max_instances)
            width = (len(self.metapaths) * k
                     * max(len(p) for p in self.metapaths))
            sample = SampleSpec(
                fanout=cfg.fanout,
                ladder=(cfg.sample_ladder
                        or default_sample_ladder(cfg.fanout, width,
                                                 cfg.layers)),
                seed=cfg.seed)
        residency = (ResidencySpec(cache_rows=cfg.cache_rows)
                     if cfg.cache_rows >= 1 else None)
        return StagePlan(
            model="magnn",
            target=self.target,
            layers=tuple(
                LayerPlan(fp=FPSpec(kind="per_type", sharded=False),
                          na=na, sa=sa, handoff="target+carry", carry=carry,
                          residency=residency)
                for l in range(cfg.layers)),
            head=HeadSpec(kind="linear"),
            metapaths=tuple(tuple(p) for p in self.metapaths),
            batch_specs=(PARTITION_BATCH_SPECS if part is not None
                         else INSTANCE_BATCH_SPECS),
            partition=part,
            sample=sample,
            schedule=(ScheduleSpec(depth=cfg.overlap)
                      if cfg.overlap >= 1 else None),
        )

    # ---------------- Stage 1: Subgraph Build (host, sampled instances) -----
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        insts = [
            mp.enumerate_instances(hg, p, cfg.max_instances, rng=rng)
            for p in self.metapaths
        ]
        return self._maybe_partition({
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "feat_dims": {t: hg.feat_dim(t) for t in hg.features},
            # node types per path position are static (plan.metapaths)
            "instances": [
                (jnp.asarray(ib.nodes), jnp.asarray(ib.mask)) for ib in insts
            ],
            "n_nodes": hg.node_counts[self.target],
        })
