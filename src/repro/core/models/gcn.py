"""GCN (Kipf & Welling) — the paper's GNN comparison baseline (§4.5, Fig. 5a).

Two stages only: Aggregation (normalized mean over neighbors) + Combination
(dense matmul). Used on the Reddit-like graph to contrast with HAN's
metapath-scaled Neighbor Aggregation.

As a :class:`StagePlan`: FP is the first Combination (``x @ w1`` — mean
aggregation and the dense matmul commute), NA covers both aggregation
layers (GCN has no semantic stage: ``sa.kind="none"``), the head is the
second Combination.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import stages
from repro.core.hgraph import HeteroGraph
from repro.core.pipeline import PlannedModel
from repro.core.plan import (FPSpec, HeadSpec, LayerPlan, NASpec,
                             ResidencySpec, SampleSpec, SASpec, ScheduleSpec,
                             StagePlan, default_sample_ladder)
from repro.data.synthetic import DATASET_TARGET


class GCN(PlannedModel):
    def __init__(self, cfg: HGNNConfig):
        super().__init__(cfg)
        self.target = DATASET_TARGET[cfg.dataset]

    def plan(self) -> StagePlan:
        if self.cfg.partitions >= 1:
            raise ValueError("gcn runs the homogeneous CSR baseline; it has "
                             "no partitioned execution layout")
        cfg = self.cfg
        sample = None
        if cfg.fanout >= 1:
            # each LayerPlan runs TWO csr aggregations -> 2 hops per layer
            sample = SampleSpec(
                fanout=cfg.fanout,
                ladder=(cfg.sample_ladder or default_sample_ladder(
                    cfg.fanout, cfg.fanout, 2 * cfg.layers)),
                seed=cfg.seed)
        residency = (ResidencySpec(cache_rows=cfg.cache_rows)
                     if cfg.cache_rows >= 1 else None)
        # one LayerPlan = one agg(relu(agg(h @ w))) block (the paper's
        # 2-conv GCN); extra layers stack that block with fresh [D, D]
        # combination weights before the classifier head
        return StagePlan(
            model="gcn",
            target=self.target,
            layers=tuple(
                LayerPlan(fp=FPSpec(kind="dense", sharded=False),
                          na=NASpec(kind="gcn", layout="csr",
                                    activation="relu"),
                          sa=SASpec(kind="none"), handoff="target",
                          residency=residency)
                for l in range(self.cfg.layers)),
            head=HeadSpec(kind="linear", param="w2"),
            sample=sample,
            # gcn's single homogeneous NA stage has no intra-layer
            # concurrency; the schedule still drives layer-to-layer async
            # dispatch and the serving prefetch thread
            schedule=(ScheduleSpec(depth=cfg.overlap)
                      if cfg.overlap >= 1 else None),
        )

    def prepare(self, hg: HeteroGraph) -> Dict:
        t = self.target
        csr = mp.build_csr(hg, [t, t])
        seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
        return self._maybe_partition({
            "x": jnp.asarray(hg.features[t]),
            "seg": jnp.asarray(seg),
            "idx": jnp.asarray(idx),
            "n_nodes": hg.node_counts[t],
            "feat_dim": hg.feat_dim(t),
        })
