"""GCN (Kipf & Welling) — the paper's GNN comparison baseline (§4.5, Fig. 5a).

Two stages only: Aggregation (normalized mean over neighbors) + Combination
(dense matmul). Used on the Reddit-like graph to contrast with HAN's
metapath-scaled Neighbor Aggregation.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import stages
from repro.core.hgraph import HeteroGraph
from repro.data.synthetic import DATASET_TARGET


class GCN:
    def __init__(self, cfg: HGNNConfig):
        self.cfg = cfg
        self.target = DATASET_TARGET[cfg.dataset]

    def prepare(self, hg: HeteroGraph) -> Dict:
        t = self.target
        csr = mp.build_csr(hg, [t, t])
        seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
        return {
            "x": jnp.asarray(hg.features[t]),
            "seg": jnp.asarray(seg),
            "idx": jnp.asarray(idx),
            "n_nodes": hg.node_counts[t],
            "feat_dim": hg.feat_dim(t),
        }

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        d_in, d = batch["feat_dim"], cfg.hidden
        return {
            "w1": jax.random.normal(k1, (d_in, d), jnp.float32) / np.sqrt(d_in),
            "w2": jax.random.normal(k2, (d, cfg.n_classes), jnp.float32) / np.sqrt(d),
        }

    # Aggregation stage (paper's GNN "Aggregation")
    def aggregate(self, batch: Dict, x: jax.Array, seg=None, idx=None) -> jax.Array:
        seg = batch["seg"] if seg is None else seg
        idx = batch["idx"] if idx is None else idx
        return stages.mean_aggregate_csr(x, seg, idx, batch["n_nodes"])

    # Combination stage
    def combine(self, w: jax.Array, h: jax.Array) -> jax.Array:
        return jax.nn.relu(h @ w)

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.combine(params["w1"], self.aggregate(batch, batch["x"]))
        return self.aggregate(batch, h) @ params["w2"]

    # stage protocol used by benchmarks (maps onto FP/NA/SA loosely)
    def fp(self, params, batch):
        return batch["x"] @ params["w1"]

    def na(self, params, batch, h):
        return jax.nn.relu(self.aggregate(batch, h))

    def sa(self, params, batch, z):
        return z  # GCN has no semantic aggregation — single semantic

    def head(self, params, z):
        return z @ params["w2"]
