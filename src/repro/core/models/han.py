"""HAN — Heterogeneous Graph Attention Network (Wang et al., WWW'19).

Stages (paper Table 1): Metapath Walk | Linear Transformation | GAT | Attention Sum.

Two execution paths:
  * baseline (``cfg.fused=False``): DGL-faithful — one CSR subgraph per
    metapath, NA runs per-subgraph (separate kernels, inter-subgraph
    parallelism NOT exploited), SA stacks the per-metapath results
    (DR-Type concat).
  * optimized (``cfg.fused=True``): stacked padded subgraphs ``[P,N,K]``,
    NA vmapped across metapaths (inter-subgraph parallelism), concat-free SA.
    With ``cfg.use_pallas`` the NA inner loop runs the Pallas kernel.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import semantics, stages
from repro.core.hgraph import HeteroGraph
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


class HAN:
    def __init__(self, cfg: HGNNConfig):
        self.cfg = cfg
        self.metapaths = DATASET_METAPATHS[cfg.dataset]
        self.target = DATASET_TARGET[cfg.dataset]

    # ---------------- Stage 1: Subgraph Build (host) ----------------
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        batch: Dict = {
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "n_nodes": hg.node_counts[self.target],
        }
        if cfg.fused:
            subs = [
                mp.build_padded(hg, p, cfg.max_degree, rng) for p in self.metapaths
            ]
            if cfg.degree_buckets > 1:
                # degree-bucketed layout: per metapath, rows binned into a
                # few K-caps (NA dispatch in stages.gat_aggregate_bucketed)
                batch["buckets"] = [
                    [(jnp.asarray(b.row_ids[i]), jnp.asarray(b.nbr[i]),
                      jnp.asarray(b.mask[i])) for i in range(b.n_buckets)]
                    for b in (mp.bucket_padded(s, cfg.degree_buckets)
                              for s in subs)
                ]
            else:
                nbr, mask = mp.stack_padded(subs)
                batch["nbr"] = jnp.asarray(nbr)  # [P, N, K]
                batch["mask"] = jnp.asarray(mask)
        else:
            edges = []
            for p in self.metapaths:
                csr = mp.build_csr(hg, p)
                seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
                edges.append((jnp.asarray(seg), jnp.asarray(idx)))
            batch["edges"] = edges
        batch["feat_dims"] = {t: hg.feat_dim(t) for t in hg.features}
        return batch

    # ---------------- params ----------------
    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg = self.cfg
        P = len(self.metapaths)
        d = cfg.hidden
        head_dim = d // cfg.n_heads
        k_fp, k_gat, k_sem, k_cls = jax.random.split(rng, 4)
        gat_keys = jax.random.split(k_gat, P)
        params = {
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            "gat": [stages.init_gat(k, cfg.n_heads, head_dim) for k in gat_keys],
            "sem": semantics.init_semantic_attention(k_sem, d, cfg.attn_hidden),
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }
        if cfg.fused and cfg.degree_buckets <= 1:
            # stacked per-metapath attention params for the one-launch path
            # (bucketed layout keeps the per-metapath list: no uniform stack)
            params["gat"] = jax.tree.map(lambda *xs: jnp.stack(xs), *params["gat"])
        return params

    # ---------------- Stage 2: Feature Projection ----------------
    def fp(self, params: Dict, batch: Dict) -> jax.Array:
        # stage-aware sharded FP (DM-Type): no-op off-mesh
        h = stages.feature_projection_sharded(params["fp"], batch["feats"])
        ht = h[self.target]
        n = ht.shape[0]
        return ht.reshape(n, self.cfg.n_heads, -1)  # [N, H, Dh]

    # ---------------- Stage 3: Neighbor Aggregation ----------------
    def na(self, params: Dict, batch: Dict, h: jax.Array):
        cfg = self.cfg
        if cfg.fused:
            if cfg.use_pallas:
                from repro.kernels import ops as kops
            if "buckets" in batch:  # degree-bucketed dispatch (per metapath)
                agg_fn = None
                if cfg.use_pallas:
                    agg_fn = lambda p, hd, hs, nn, mm: kops.gat_aggregate(
                        p, hd, hs, nn, mm, use_pallas=True)
                z = jnp.stack([
                    stages.gat_aggregate_bucketed(p_i, h, h, bks, agg_fn=agg_fn)
                    for p_i, bks in zip(params["gat"], batch["buckets"])
                ])  # [P, N, H, Dh]
            else:
                stacked_fn = None
                if cfg.use_pallas:
                    # ONE fused kernel launch for the whole [P, N, K] stack
                    stacked_fn = lambda pp, hd, hs, nn, mm: (
                        kops.gat_aggregate_stacked(pp, hd, hs, nn, mm,
                                                   use_pallas=True))
                z = stages.gat_aggregate_padded_stacked(
                    params["gat"], h, batch["nbr"], batch["mask"],
                    stacked_fn=stacked_fn)
            z = jax.nn.elu(z)  # [P, N, H, Dh]
            return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]
        # baseline: independent kernels per subgraph (the paper's Fig. 5c timeline)
        outs: List[jax.Array] = []
        for p_i, (seg, idx) in zip(params["gat"], batch["edges"]):
            z = stages.gat_aggregate_csr(p_i, h, h, seg, idx, batch["n_nodes"])
            outs.append(jax.nn.elu(z).reshape(z.shape[0], -1))
        return outs  # list of [N, D]

    # ---------------- Stage 4: Semantic Aggregation ----------------
    def sa(self, params: Dict, batch: Dict, z) -> jax.Array:
        if self.cfg.fused:
            # SA rides the NA layout: [P, N, D] with nodes over BATCH
            z = stages.shard(z, *stages.HGNN_STAGE_SPECS["sa_stacked"])
            return semantics.semantic_attention(params["sem"], z)
        return semantics.semantic_attention_list(params["sem"], z)

    def head(self, params: Dict, z: jax.Array) -> jax.Array:
        return z @ params["cls"]

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.fp(params, batch)
        z = self.na(params, batch, h)
        return self.head(params, self.sa(params, batch, z))
