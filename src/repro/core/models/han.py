"""HAN — Heterogeneous Graph Attention Network (Wang et al., WWW'19).

Stages (paper Table 1): Metapath Walk | Linear Transformation | GAT | Attention Sum.

Execution is declared as a :class:`StagePlan` and run by the stage-graph
executor (:mod:`repro.core.pipeline`); this module only owns the host-side
Subgraph Build and the plan:

  * baseline (``cfg.fused=False``): NA layout ``csr`` — one CSR subgraph per
    metapath, separate kernels, SA pays the DR-Type concat.
  * optimized (``cfg.fused=True``): layout ``stacked`` ``[P, N, K]``
    (inter-subgraph parallelism, concat-free SA) or ``bucketed`` when
    ``cfg.degree_buckets > 1``.  ``cfg.use_pallas`` runs the fused GAT-NA
    kernel; ``cfg.fuse_na_sa`` additionally fuses the SA pass-1 epilogue
    into the NA kernel (stacked layout only).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HGNNConfig
from repro.core import metapath as mp
from repro.core import stages
from repro.core.hgraph import HeteroGraph
from repro.core.pipeline import PlannedModel
from repro.core.plan import (BUCKETED_BATCH_SPECS, PARTITION_BATCH_SPECS,
                             STACKED_BATCH_SPECS, FPSpec, HeadSpec, LayerPlan,
                             NASpec, PartitionSpec, ResidencySpec, SampleSpec,
                             SASpec, ScheduleSpec, StagePlan,
                             default_sample_ladder)
from repro.data.synthetic import DATASET_METAPATHS, DATASET_TARGET


class HAN(PlannedModel):
    def __init__(self, cfg: HGNNConfig):
        super().__init__(cfg)
        self.metapaths = DATASET_METAPATHS[cfg.dataset]
        self.target = DATASET_TARGET[cfg.dataset]

    def plan(self) -> StagePlan:
        cfg = self.cfg
        if not cfg.fused:
            layout = "csr"
        elif cfg.degree_buckets > 1:
            layout = "bucketed"
        else:
            layout = "stacked"
        part = None
        if cfg.partitions >= 1:
            if layout != "stacked":
                raise ValueError(
                    "partitioned HAN execution needs the stacked layout "
                    "(fused=True, no degree buckets); got "
                    f"layout={layout!r}")
            part = PartitionSpec(k=cfg.partitions)
        na = NASpec(kind="gat", layout=layout, activation="elu",
                    use_pallas=cfg.use_pallas)
        sa = SASpec(kind="attention", stacked=cfg.fused,
                    fuse_epilogue=(cfg.fuse_na_sa and layout == "stacked"
                                   and part is None))
        sample = None
        if cfg.fanout >= 1:
            # per-hop width: every metapath contributes up to the padded
            # table's effective fan-out per target row
            k = min(cfg.fanout, cfg.max_degree)
            sample = SampleSpec(
                fanout=cfg.fanout,
                ladder=(cfg.sample_ladder or default_sample_ladder(
                    cfg.fanout, len(self.metapaths) * k, cfg.layers)),
                seed=cfg.seed)
        residency = (ResidencySpec(cache_rows=cfg.cache_rows)
                     if cfg.cache_rows >= 1 else None)
        # layer 0 projects the raw per-type features; the metapath graphs
        # are target->target, so every hidden layer re-projects only the
        # previous SA output (a dense [D, D] matmul, reshaped to heads)
        return StagePlan(
            model="han",
            target=self.target,
            layers=tuple(
                LayerPlan(
                    fp=(FPSpec(kind="per_type", sharded=True, heads=True)
                        if l == 0 else
                        FPSpec(kind="dense", sharded=True, heads=True)),
                    na=na, sa=sa, handoff="target", residency=residency)
                for l in range(cfg.layers)),
            head=HeadSpec(kind="linear"),
            metapaths=tuple(tuple(p) for p in self.metapaths),
            batch_specs=(PARTITION_BATCH_SPECS if part is not None
                         else BUCKETED_BATCH_SPECS if layout == "bucketed"
                         else STACKED_BATCH_SPECS),
            partition=part,
            sample=sample,
            schedule=(ScheduleSpec(depth=cfg.overlap)
                      if cfg.overlap >= 1 else None),
        )

    # ---------------- Stage 1: Subgraph Build (host) ----------------
    def prepare(self, hg: HeteroGraph) -> Dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        batch: Dict = {
            "feats": {t: jnp.asarray(f) for t, f in hg.features.items()},
            "n_nodes": hg.node_counts[self.target],
        }
        if cfg.fused:
            subs = [
                mp.build_padded(hg, p, cfg.max_degree, rng) for p in self.metapaths
            ]
            if cfg.degree_buckets > 1:
                # degree-bucketed layout: per metapath, rows binned into a
                # few K-caps (executor dispatches gat_aggregate_bucketed)
                batch["buckets"] = [
                    [(jnp.asarray(b.row_ids[i]), jnp.asarray(b.nbr[i]),
                      jnp.asarray(b.mask[i])) for i in range(b.n_buckets)]
                    for b in (mp.bucket_padded(s, cfg.degree_buckets)
                              for s in subs)
                ]
            else:
                nbr, mask = mp.stack_padded(subs)
                batch["nbr"] = jnp.asarray(nbr)  # [P, N, K]
                batch["mask"] = jnp.asarray(mask)
        else:
            edges = []
            for p in self.metapaths:
                csr = mp.build_csr(hg, p)
                seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
                edges.append((jnp.asarray(seg), jnp.asarray(idx)))
            batch["edges"] = edges
        batch["feat_dims"] = {t: hg.feat_dim(t) for t in hg.features}
        return self._maybe_partition(batch)
