"""HGNN model zoo: the paper's three HGNNs (RGCN, HAN, MAGNN) + the GCN
comparison baseline. Each module exposes a ``Model`` class with:

  * ``prepare(hg)``       host-side Subgraph Build -> device batch (stage 1)
  * ``init(rng, batch)``  parameter pytree
  * ``fp / na / sa / head`` per-stage pure functions (for stage benchmarks)
  * ``forward``           full inference = head(sa(na(fp(...))))
"""
from repro.core.models.han import HAN
from repro.core.models.rgcn import RGCN
from repro.core.models.magnn import MAGNN
from repro.core.models.gcn import GCN

from repro.configs.base import HGNNConfig


def get_model(cfg: HGNNConfig):
    return {"han": HAN, "rgcn": RGCN, "magnn": MAGNN, "gcn": GCN}[cfg.model](cfg)
