"""Heterogeneous graph container (host side).

A :class:`HeteroGraph` is the paper's HG: multiple node types, multiple edge
types (relations).  Relations are stored as ``scipy.sparse`` CSR adjacency
matrices with shape ``(n_src, n_dst)``.  All of *Subgraph Build* (metapath /
relation walk) happens on the host with scipy — matching the paper's
observation that Subgraph Build "is executed in CPU before inference phase".

Device-side layouts produced from this container live in
:mod:`repro.core.metapath`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np
import scipy.sparse as sp

Relation = Tuple[str, str, str]  # (src_type, rel_name, dst_type)


@dataclass
class HeteroGraph:
    # node type -> count
    node_counts: Dict[str, int]
    # node type -> [n_type, feat_dim] float32 raw features (per-type dims differ!)
    features: Dict[str, np.ndarray]
    # (src_type, rel_name, dst_type) -> csr (n_src, n_dst)
    relations: Dict[Relation, sp.csr_matrix]
    name: str = "hg"

    def rel(self, src: str, dst: str) -> sp.csr_matrix:
        """Find the (unique) relation src->dst by node types."""
        for (s, _, d), a in self.relations.items():
            if s == src and d == dst:
                return a
        raise KeyError(f"no relation {src}->{dst} in {self.name}")

    @property
    def n_edges(self) -> int:
        return int(sum(a.nnz for a in self.relations.values()))

    def feat_dim(self, t: str) -> int:
        return int(self.features[t].shape[1])

    def in_neighbors(self, key: Relation, u: int) -> np.ndarray:
        """Global source ids with an edge into destination node ``u`` under
        ``key`` — the request-path sampler's ground truth: every neighbor a
        sampled minibatch wires for (key, u) must be in this set."""
        adj_in = self.relations[key].T.tocsr()
        return adj_in.indices[adj_in.indptr[u]: adj_in.indptr[u + 1]]

    def validate(self) -> None:
        for (s, r, d), a in self.relations.items():
            assert a.shape == (self.node_counts[s], self.node_counts[d]), (
                f"relation {(s, r, d)} shape {a.shape} != "
                f"({self.node_counts[s]}, {self.node_counts[d]})"
            )
        for t, n in self.node_counts.items():
            assert self.features[t].shape[0] == n, t


def metapath_adjacency(hg: HeteroGraph, node_path: List[str]) -> sp.csr_matrix:
    """Adjacency of metapath-based neighbors: product of relation adjacencies.

    ``node_path`` is the node-type sequence, e.g. ``["M", "D", "M"]`` for the
    MDM metapath.  Returns a binarized csr of shape ``(n_t0, n_tL)``: entry
    (u, v) != 0 iff v is a metapath-based neighbor of u.
    """
    assert len(node_path) >= 2
    acc = hg.rel(node_path[0], node_path[1]).astype(np.float32)
    for a, b in zip(node_path[1:-1], node_path[2:]):
        acc = acc @ hg.rel(a, b).astype(np.float32)
        acc.data = np.minimum(acc.data, 1.0)  # binarize counts to reachability
    acc = acc.tocsr()
    acc.data = np.ones_like(acc.data)
    acc.eliminate_zeros()
    return acc


def sparsity(a: sp.csr_matrix) -> float:
    """Fraction of *zero* entries (the paper's Fig. 6a metric)."""
    return 1.0 - a.nnz / float(a.shape[0] * a.shape[1])
