"""The stage-graph executor: one interpreter for every :class:`StagePlan`.

Model classes used to own the dispatch ladder (baseline CSR vs fused
resident vs streaming vs bucketed vs sharded vs pallas-vs-ref) — three
copies of it, one per HGNN.  Here it lives once: the executor resolves
layout, kernel dispatch, sharding constraints and interpret/pallas mode from
the plan, and the models shrink to host-side ``prepare()`` plus a plan
builder (:class:`PlannedModel`).

A plan is an **L-layer stack** (:class:`repro.core.plan.LayerPlan`): the
executor loops FP→NA→SA per layer with the per-type intermediate feature
tables as the carried state, reusing the layer-invariant host-side layouts
(padded/stacked/bucketed index maps, degree buckets, instance LUTs, halo
maps) built once in ``prepare()``.  Layer 0's parameters live at the pytree
root — ``cfg.layers=1`` is bit-exact with the pre-multi-layer path — and
hidden layers ride ``params["layers"][l-1]`` with the same leaf names, so
the declarative sharding rule tables cover them for free.

The executor also owns the paper's two structural optimizations:

* **Graph-partitioned execution** (``plan.partition``): the vertex/feature
  tables are split into K edge-cut partitions (``repro.dist.partition``);
  FP and NA run per-partition on local shards and the halo feature exchange
  between them is an explicit ``gather_halo`` stage (shard_map over the
  BATCH axes when the mesh divides K).  SA runs unchanged on the
  partition-local stacks — its score pass reduces per-partition partials,
  so the only other communication is a [K, P]-sized reduce.  The halo
  *maps* are graph-invariant, so an L-layer stack re-runs ``gather_halo``
  per layer on the *updated* features (total exchanged traffic =
  halo-bytes × L; ``characterize.partition_traffic`` reports it).

* **Fused NA→SA epilogue** (``plan.sa.fuse_epilogue``): on the stacked
  layout the semantic-score pass-1 partial (``mean_n q·tanh(z W + b)``)
  accumulates inside the NA kernel while each ``z`` tile is in VMEM —
  one full ``[P, N, D]`` HBM read disappears, and SA degenerates to a
  softmax over ``P`` plus the weighted combine (exactly one ``z`` read).
* **Per-stage characterization records** (:meth:`stage_records`): every
  stage function is lowered and walked by ``core/characterize.py``, so
  benchmarks report the paper's Fig. 3-style breakdown from the same code
  path that serves traffic.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semantics, stages
from repro.core.plan import StagePlan
from repro.dist.sharding import BATCH, MODEL

_ACT = {None: lambda x: x, "elu": jax.nn.elu, "relu": jax.nn.relu}


def _kops():
    """Kernel dispatch goes through the module attribute so tests can
    monkeypatch wrappers into interpret mode."""
    from repro.kernels import ops

    return ops


class StageGraphExecutor:
    """Executes a :class:`StagePlan` over a prepared device batch."""

    def __init__(self, plan: StagePlan, cfg):
        self.plan = plan
        self.cfg = cfg
        # per-stage jit cache for the async schedule driver: one traced
        # callable per stage name, reused across forward_overlapped calls
        # (shapes key jax.jit's own cache below it)
        self._ov_jit: Dict = {}
        # last forward_overlapped dispatch trace (tests / accounting)
        self.last_dispatch: Dict = {}

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        params = self._init_layer0(rng, batch)
        if self.plan.n_layers > 1:
            # hidden-layer params mirror the root leaf names under
            # params["layers"][l-1] (the sharding rule tables match on leaf
            # name + rank, so they cover the stack for free); fold_in keeps
            # the layer-0 RNG stream untouched -> layers=1 stays bit-exact
            params["layers"] = [
                self._init_hidden_layer(jax.random.fold_in(rng, l), batch)
                for l in range(1, self.plan.n_layers)
            ]
        return params

    def _init_layer0(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg, plan = self.cfg, self.plan
        d = cfg.hidden
        if plan.na.kind == "gcn":
            k1, k2 = jax.random.split(rng)
            d_in = batch["feat_dim"]
            return {
                "w1": jax.random.normal(k1, (d_in, d), jnp.float32) / np.sqrt(d_in),
                "w2": jax.random.normal(k2, (d, cfg.n_classes), jnp.float32)
                / np.sqrt(d),
            }
        k_fp, k_na, k_sem, k_cls = jax.random.split(rng, 4)
        params: Dict = {
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }
        params.update(self._init_na_sa(k_na, k_sem, batch))
        return params

    def _init_na_sa(self, k_na: jax.Array, k_sem: jax.Array,
                    batch: Dict) -> Dict:
        """The NA/SA parameter block shared by layer 0 and every hidden
        layer: gat stacks / instance attention + semantic attention, or
        per-relation ``w_rel`` + per-type ``w_self``.  RNG consumption is
        identical to the pre-multi-layer init, so layer 0 stays bit-exact."""
        cfg, plan = self.cfg, self.plan
        d = cfg.hidden
        head_dim = d // cfg.n_heads
        p: Dict = {}
        if plan.na.kind == "gat":
            keys = jax.random.split(k_na, len(plan.metapaths))
            gat = [stages.init_gat(k, cfg.n_heads, head_dim) for k in keys]
            if plan.na.layout == "stacked":
                # one stacked param set -> ONE kernel launch for the stack
                # (bucketed keeps the per-metapath list: no uniform K)
                gat = jax.tree.map(lambda *xs: jnp.stack(xs), *gat)
            p["gat"] = gat
            p["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "instance":
            keys = jax.random.split(k_na, len(plan.metapaths))
            p["att"] = [
                stages.init_instance_attention(k, cfg.n_heads, head_dim)
                for k in keys
            ]
            p["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "mean":
            rel_keys = sorted(batch["rels"])
            rel_ks = jax.random.split(k_na, max(len(rel_keys), 1))
            self_ks = jax.random.split(k_sem, len(batch["counts"]))
            p["w_rel"] = {
                key: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for key, k in zip(rel_keys, rel_ks)
            }
            p["w_self"] = {
                t: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for t, k in zip(sorted(batch["counts"]), self_ks)
            }
        return p

    def _init_hidden_layer(self, rng: jax.Array, batch: Dict) -> Dict:
        """Params for one layer >= 1: the hidden FP (square [D, D]
        re-projections of the carried tables, or nothing for ``identity``)
        plus a fresh copy of the layer's NA/SA attention/relation weights."""
        cfg, plan = self.cfg, self.plan
        d = cfg.hidden
        if plan.na.kind == "gcn":
            return {"fp": jax.random.normal(rng, (d, d), jnp.float32)
                    / np.sqrt(d)}
        k_fp, k_na, k_sem = jax.random.split(rng, 3)
        p: Dict = {}
        if plan.na.kind == "gat":
            p["fp"] = jax.random.normal(k_fp, (d, d), jnp.float32) / np.sqrt(d)
        elif plan.na.kind == "instance":
            # carry is layer-uniform (StagePlan.__post_init__)
            types = tuple(sorted(set(plan.layers[0].carry) | {plan.target}))
            fp_ks = jax.random.split(k_fp, len(types))
            p["fp"] = {
                t: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for t, k in zip(types, fp_ks)
            }
        p.update(self._init_na_sa(k_na, k_sem, batch))
        return p

    def _layer_params(self, params: Dict, l: int) -> Dict:
        """Layer ``l``'s parameter dict: layer 0 lives at the pytree root
        (bit-exact with the single-layer layout), hidden layers under
        ``params["layers"][l-1]`` with the same leaf names."""
        return params if l == 0 else params["layers"][l - 1]

    # ------------------------------------------------------------------
    # Stage 2: Feature Projection
    # ------------------------------------------------------------------
    def fp(self, params: Dict, batch: Dict):
        plan = self.plan
        if plan.partition is not None:
            return self._fp_partitioned(params, batch)
        if plan.fp.kind == "dense":
            return batch["x"] @ params["w1"]
        project = (stages.feature_projection_sharded if plan.fp.sharded
                   else stages.feature_projection)
        h = project(params["fp"], batch["feats"])
        if plan.fp.heads:
            ht = h[plan.target]
            return ht.reshape(ht.shape[0], self.cfg.n_heads, -1)  # [N, H, Dh]
        return h

    def _fp_partitioned(self, params: Dict, batch: Dict) -> Dict:
        """FP over the per-partition owned feature shards: [K, n_t, F_t] @
        W_t per type — pure data parallelism over the partition dim."""
        plan = self.plan
        out: Dict = {}
        for t, f in batch["part"]["feats"].items():
            w = params["fp"][t]
            if plan.fp.sharded:
                w = stages.shard(w, *stages.HGNN_STAGE_SPECS["fp_weight"])
            out[t] = stages.shard(f @ w, BATCH, None, MODEL)
        return out

    def _fp_hidden(self, lp, p_l: Dict, state):
        """FP for layers >= 1: project the carried per-type feature tables
        (``[N_t, D]`` single-table, ``[K, n_t, D]`` partitioned — the matmul
        broadcasts over the partition dim).  ``identity`` passes the state
        through (RGCN: the relation weights are the layer's transform)."""
        plan = self.plan
        if lp.fp.kind == "identity":
            return state
        if lp.fp.kind == "per_type":
            project = (stages.feature_projection_sharded if lp.fp.sharded
                       else stages.feature_projection)
            return project(p_l["fp"], state)
        # dense: a single [D, D] re-projection of the carried target table
        w = p_l["fp"]
        if lp.fp.sharded:
            w = stages.shard(w, *stages.HGNN_STAGE_SPECS["fp_weight"])
        x = state[plan.target]
        if plan.partition is not None:
            # keep the dict shape gather_halo expects; heads reshaping
            # happens inside the partitioned NA (as in layer 0)
            return {plan.target: stages.shard(x @ w, BATCH, None, MODEL)}
        h = x @ w
        if lp.fp.sharded:
            h = stages.shard(h, *stages.HGNN_STAGE_SPECS["fp_out"])
        if lp.fp.heads:
            return h.reshape(h.shape[0], self.cfg.n_heads, -1)  # [N, H, Dh]
        return h

    def _handoff(self, lp, batch: Dict, h, out):
        """Package one layer's outputs as the next layer's carried state —
        the device-side realization of ``LayerPlan.handoff``.  ``h`` is this
        layer's FP output (post-``gather_halo`` in the partitioned flow),
        ``out`` its SA output."""
        plan = self.plan
        if lp.handoff == "all":
            return out  # rel_sum SA already returned every type's table
        state = {plan.target: out}
        if lp.handoff == "target+carry":
            if plan.partition is not None:
                part = batch["part"]
                for ty in lp.carry:  # owned rows only; halos re-exchange
                    state[ty] = h[ty][:, : part["feats"][ty].shape[1]]
            else:
                for ty in lp.carry:
                    state[ty] = h[ty]
        return state

    # ------------------------------------------------------------------
    # partitioned flow: the halo feature exchange (the new explicit stage)
    # ------------------------------------------------------------------
    def halo_exchange(self, batch: Dict, h_own: Dict) -> Dict:
        """Exchange-only half of :meth:`gather_halo`: fetch each type's
        halo rows from the other partitions' owned tables — WITHOUT
        appending them to the local pool.  The async schedule dispatches
        this concurrently with NA's owned-rows pre-gather (both depend
        only on FP); the serial path concatenates right below."""
        from repro.dist.partition import gather_halo as _gather

        part = batch["part"]
        mode = self.plan.partition.halo
        res = batch.get("residency")
        out: Dict = {}
        for t, h in h_own.items():
            halo = _gather(h, part["halo_src"][t], mode=mode)
            if res is not None and t in res.get("halo_slot", {}):
                # residency arm (hot-halo path): halo entries whose global
                # vertex is hot are overlaid from the partition-local cache
                # — bitwise copies of owned rows — so they skip the
                # exchange.  Pure indexing: bit-exact under both the
                # shard_map and flat gather lowerings.
                slot = res["halo_slot"][t]  # [K, H_max] (-1 = cold/pad)
                tail = h.shape[2:]
                cache = h.reshape((-1,) + tail)[res["hot_flat"][t]]
                sel = jnp.take(cache, jnp.clip(slot, 0), axis=0)
                cond = (slot >= 0).reshape(slot.shape + (1,) * len(tail))
                halo = jnp.where(cond, sel, halo)
            out[t] = halo
        return out

    def gather_halo(self, batch: Dict, h_own: Dict):
        """Fetch each type's halo rows from the other partitions' owned
        tables and append them: local source table = concat(own, halo).
        The one communication step of the partitioned flow (shard_map
        all-gather on a dividing mesh; see ``repro.dist.partition``)."""
        halos = self.halo_exchange(batch, h_own)
        return {t: jnp.concatenate([h, halos[t]], axis=1)
                for t, h in h_own.items()}

    # ------------------------------------------------------------------
    # Stage 3: Neighbor Aggregation
    # ------------------------------------------------------------------
    def _res_pool(self, batch: Dict, t: str, x):
        """Residency dispatch arm (``plan.residency`` + a prepared batch
        that carries the hot sets): extend type ``t``'s source pool with
        the resident cache section — bitwise copies of the hot rows, which
        the remapped index tables address instead of re-gathering the
        scattered HBM rows.  The hot sets are layer-invariant, so every
        layer of an L-layer stack reuses the same resident rows (HiHGNN
        inter-layer reuse).  Sampled/uncached batches pass through."""
        res = batch.get("residency")
        if res is None or "hot" not in res or t not in res["hot"]:
            return x
        return jnp.concatenate([x, jnp.take(x, res["hot"][t], axis=0)],
                               axis=0)

    def na(self, params: Dict, batch: Dict, h):
        kind = self.plan.na.kind
        if self.plan.partition is not None:
            return self._na_partitioned(params, batch, h)
        if kind == "gat":
            return self._na_gat(params, batch, h)
        if kind == "mean":
            return self._na_mean(params, batch, h)
        if kind == "instance":
            return self._na_instance(params, batch, h)
        if kind == "gcn":
            # both GCN aggregation layers are NA work (the paper's GNN
            # comparison has no semantic stage); the segment count comes
            # from h's static shape so the forward stays jit-able with the
            # batch as an argument (batch["n_nodes"] would be a tracer).
            # The residency pool covers both aggregations — the second one
            # re-gathers z over the same remapped index table, which is the
            # inter-layer reuse in its purest form.
            t = self.plan.target
            z = jax.nn.relu(stages.mean_aggregate_csr(
                self._res_pool(batch, t, h), batch["seg"], batch["idx"],
                h.shape[0]))
            return stages.mean_aggregate_csr(
                self._res_pool(batch, t, z), batch["seg"], batch["idx"],
                z.shape[0])
        raise ValueError(f"unknown NA kind {kind!r}")

    def _na_gat(self, params: Dict, batch: Dict, h: jax.Array):
        plan, cfg = self.plan, self.cfg
        act = _ACT[plan.na.activation]
        # residency arm: the gather pool is the target table extended with
        # the resident hot-row section (uncached batches: pool is h itself)
        pool = self._res_pool(batch, plan.target, h)
        if plan.na.layout == "csr":
            # baseline: independent kernels per subgraph (paper Fig. 5c).
            # h [N, H, Dh] covers the target nodes, so its static leading
            # dim is the segment count (jit-safe: batch["n_nodes"] traces).
            outs: List[jax.Array] = []
            for p_i, (seg, idx) in zip(params["gat"], batch["edges"]):
                z = stages.gat_aggregate_csr(p_i, h, pool, seg, idx,
                                             h.shape[0])
                outs.append(act(z).reshape(z.shape[0], -1))
            return outs  # list of [N, D]
        if plan.na.layout == "bucketed":
            agg_fn = None
            if plan.na.use_pallas:
                kops = _kops()
                agg_fn = lambda p, hd, hs, nn, mm: kops.gat_aggregate(
                    p, hd, hs, nn, mm, use_pallas=True)
            z = jnp.stack([
                stages.gat_aggregate_bucketed(p_i, h, pool, bks,
                                              agg_fn=agg_fn)
                for p_i, bks in zip(params["gat"], batch["buckets"])
            ])  # [P, N, H, Dh]
            z = act(z)
            return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]
        # stacked layout: ONE launch for the whole [P, N, K] stack
        if plan.sa.fuse_epilogue:
            return self._na_gat_fused_sa(params, batch, h)
        stacked_fn = None
        if plan.na.use_pallas:
            kops = _kops()
            stacked_fn = lambda pp, hd, hs, nn, mm: kops.gat_aggregate_stacked(
                pp, hd, hs, nn, mm, use_pallas=True)
        z = stages.gat_aggregate_padded_stacked(
            params["gat"], h, batch["nbr"], batch["mask"],
            stacked_fn=stacked_fn, h_src=pool)
        z = act(z)
        return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]

    def _na_gat_fused_sa(self, params: Dict, batch: Dict, h: jax.Array):
        """Stacked NA with the SA pass-1 epilogue fused in: returns
        ``(z [P, N, D] activation applied, wp [P] semantic-score means)``."""
        if self.plan.na.activation != "elu":
            # the kernel epilogue bakes the NA activation in (elu); a plan
            # declaring another activation would silently diverge
            raise ValueError("sa.fuse_epilogue requires na.activation='elu' "
                             f"(got {self.plan.na.activation!r})")
        kops = _kops()
        specs = stages.HGNN_STAGE_SPECS
        h_src = stages.shard(self._res_pool(batch, self.plan.target, h),
                             *specs["na_src"])
        nbr = stages.shard(batch["nbr"], None, *specs["na_nbr"])
        mask = stages.shard(batch["mask"], None, *specs["na_nbr"])
        z4, wp = kops.gat_aggregate_stacked_fused_sa(
            params["gat"], h, h_src, nbr, mask, params["sem"],
            use_pallas=self.plan.na.use_pallas)
        z4 = stages.shard(z4, None, *specs["na_out"])
        return z4.reshape(z4.shape[0], z4.shape[1], -1), wp

    def _na_mean(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        plan = self.plan
        # "__h__" rides along for the self-loop term in SA (rel_sum)
        out: Dict = {"__h__": h}
        agg_fn = None
        if plan.na.use_pallas and plan.na.layout != "csr":
            kops = _kops()
            agg_fn = lambda hs, nn, mm: kops.segment_spmm(
                hs, nn, mm, mean=True, use_pallas=True)
        for key in sorted(batch["rels"]):
            s, r, d = key
            rel = batch["rels"][key]
            # residency arm: cache-extended per-source-type gather pool
            pool = self._res_pool(batch, s, h[s])
            if plan.na.layout == "csr":
                # h[d]'s static leading dim is the destination-type count
                # (jit-safe: batch["counts"] values trace)
                agg = stages.mean_aggregate_csr(pool, rel[0], rel[1],
                                                h[d].shape[0])
            elif plan.na.layout == "bucketed":
                # the destination table's static leading dim is the row
                # count (jit-safe; for full-graph batches the bucket row_ids
                # partition exactly those rows, for sampled rung-padded
                # buckets the out-of-range pad row_ids scatter-drop)
                agg = stages.mean_aggregate_bucketed(
                    pool, rel, h[d].shape[0], agg_fn=agg_fn)
            else:  # padded
                agg = stages.mean_aggregate_padded_sharded(
                    pool, rel[0], rel[1], agg_fn=agg_fn)
            out["|".join(key)] = agg @ params["w_rel"][key]
        return out

    def _na_instance_one(self, params: Dict, batch: Dict,
                         h: Dict[str, jax.Array], i_path: int) -> jax.Array:
        """One metapath's instance-attention NA — the serial loop body and
        the async schedule's per-metapath stage share it verbatim."""
        plan, cfg = self.plan, self.cfg
        specs = stages.HGNN_STAGE_SPECS
        H = cfg.n_heads
        act = _ACT[plan.na.activation]
        res = batch.get("residency")
        hot = res["hot"] if res is not None and "hot" in res else {}
        p_i = params["att"][i_path]
        nodes, mask = batch["instances"][i_path]
        types = plan.metapaths[i_path]
        nodes = stages.shard(nodes, *specs["na_inst_nodes"])
        mask = stages.shard(mask, *specs["na_nbr"])
        n, i, l = nodes.shape

        # gather projected features per path position (types are static,
        # carried by the plan); the residency arm serves the remapped
        # instance tables through the VMEM-resident cache gather
        def gather(j):
            ty = types[j]
            if ty in hot:
                return _kops().cached_gather(
                    h[ty], hot[ty], nodes[:, :, j],
                    use_pallas=plan.na.use_pallas)
            return h[ty][nodes[:, :, j]]

        h_path = jnp.stack(
            [gather(j) for j in range(l)], axis=2
        )  # [N, I, L, D]
        h_path = h_path.reshape(n, i, l, H, -1)
        enc = stages.rotate_encoder(h_path)  # [N, I, H, Dh]
        h_tgt = h[plan.target].reshape(-1, H, h_path.shape[-1])
        if plan.na.use_pallas:
            # Instance attention IS padded GAT NA with the encoded
            # instances as the source pool (arange neighbor grid).
            kops = _kops()
            flat = enc.reshape(n * i, H, enc.shape[-1])
            nbr_inst = jnp.arange(n * i, dtype=jnp.int32).reshape(n, i)
            z = kops.gat_aggregate(p_i, h_tgt, flat, nbr_inst, mask,
                                   use_pallas=True)
        else:
            z = stages.instance_aggregate(p_i, h_tgt, enc, mask)
        z = act(z).reshape(n, -1)
        return stages.shard(z, *specs["na_flat_out"])  # [N, D]

    def _na_instance(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        return [self._na_instance_one(params, batch, h, i)
                for i in range(len(self.plan.metapaths))]

    def _na_metapath(self, params: Dict, batch: Dict, h, i: int):
        """One metapath's NA as its own schedulable stage (async schedule,
        single-device): the bucketed / csr GAT loop body or one MAGNN
        instance-attention round.  The only delta vs the serial loop is
        *where* the activation applies — per-metapath here vs post-stack
        there — which is elementwise, so SA's re-stack is bitwise equal."""
        plan = self.plan
        act = _ACT[plan.na.activation]
        if plan.na.kind == "instance":
            return self._na_instance_one(params, batch, h, i)
        pool = self._res_pool(batch, plan.target, h)
        if plan.na.layout == "csr":
            seg, idx = batch["edges"][i]
            z = stages.gat_aggregate_csr(params["gat"][i], h, pool, seg, idx,
                                         h.shape[0])
            return act(z).reshape(z.shape[0], -1)  # [N, D]
        agg_fn = None
        if plan.na.use_pallas:
            kops = _kops()
            agg_fn = lambda p, hd, hs, nn, mm: kops.gat_aggregate(
                p, hd, hs, nn, mm, use_pallas=True)
        z = stages.gat_aggregate_bucketed(params["gat"][i], h, pool,
                                          batch["buckets"][i], agg_fn=agg_fn)
        return act(z).reshape(z.shape[0], -1)  # [N, D]

    def _na_partitioned(self, params: Dict, batch: Dict, h_loc: Dict):
        """NA over partition-local shards: destinations are the owned rows,
        sources the concat(own, halo) local tables built by ``gather_halo``.
        Runs the XLA padded path vmapped over the partition dim (fusing the
        Pallas kernels into the per-partition body is future work)."""
        plan, cfg = self.plan, self.cfg
        part = batch["part"]
        t = plan.target
        act = _ACT[plan.na.activation]
        H = cfg.n_heads
        if plan.na.kind == "gat":
            n_own = part["feats"][t].shape[1]
            heads = lambda x: x.reshape(x.shape[0], x.shape[1], H, -1)
            hd = heads(h_loc[t][:, :n_own])  # [K, n, H, Dh] owned rows
            hs = heads(h_loc[t])  # [K, n+halo, H, Dh] local source pool

            def one_part(hd_k, hs_k, nbr_k, mask_k):  # nbr_k [P, n, Kd]
                return jax.vmap(
                    lambda pp, nn, mm: stages.gat_aggregate_padded(
                        pp, hd_k, hs_k, nn, mm),
                    in_axes=(0, 0, 0))(params["gat"], nbr_k, mask_k)

            z = jax.vmap(one_part)(hd, hs, part["nbr"], part["mask"])
            z = act(z)  # [K, P, n, H, Dh]
            z = z.reshape(z.shape[0], z.shape[1], z.shape[2], -1)
            return stages.shard(z, BATCH, None, None, None)  # [K, P, n, D]
        if plan.na.kind == "mean":
            if plan.n_layers > 1:
                # multi-layer partitioning relabels EVERY relation (each
                # destination type aggregates on its own owners); carry the
                # per-type owned rows for the rel_sum self-loop
                out: Dict = {"__h__": {
                    ty: h_loc[ty][:, : part["feats"][ty].shape[1]]
                    for ty in part["feats"]
                }}
            else:
                out = {"__h__": h_loc[t][:, : part["feats"][t].shape[1]]}
            for key in sorted(part["rels"]):
                s = key[0]
                nbr, mask = part["rels"][key]
                agg = jax.vmap(stages.mean_aggregate_padded)(
                    h_loc[s], nbr, mask)  # [K, n_d, D]
                out["|".join(key)] = agg @ params["w_rel"][key]
            return out
        if plan.na.kind == "instance":
            h_tgt = h_loc[t][:, : part["feats"][t].shape[1]]
            h_tgt = h_tgt.reshape(h_tgt.shape[0], h_tgt.shape[1], H, -1)
            outs: List[jax.Array] = []
            for p_i, (nodes, mask), types in zip(params["att"],
                                                 part["instances"],
                                                 plan.metapaths):
                k_, n, i, l = nodes.shape
                h_path = jnp.stack(
                    [jax.vmap(lambda hh, idx: hh[idx])(
                        h_loc[types[j]], nodes[:, :, :, j])
                     for j in range(l)], axis=3)  # [K, n, I, L, D]
                h_path = h_path.reshape(k_, n, i, l, H, -1)
                enc = jax.vmap(stages.rotate_encoder)(h_path)  # [K, n, I, H, Dh]
                z = jax.vmap(stages.instance_aggregate, in_axes=(None, 0, 0, 0))(
                    p_i, h_tgt, enc, mask)
                outs.append(act(z).reshape(k_, n, -1))  # [K, n, D]
            return outs
        raise ValueError(
            f"no partitioned NA path for kind {plan.na.kind!r}")

    # ------------------------------------------------------------------
    # partitioned flow, async schedule: the own/halo NA split.
    #
    # Serial partitioned NA gathers from concat(own, halo) — it cannot
    # start until the exchange lands.  But a gather is a pure row
    # selection, so it splits at the *gather*, never at a float
    # reduction: the owned-side rows (and the per-row source attention
    # scores, which are row-local EW math) pre-gather against the owned
    # table alone while the exchange is still in flight, and the merge
    # where-selects the halo side in afterwards (stages.gather_own /
    # gather_merge — bitwise equal to the concat-then-gather).  All the
    # attention / mean arithmetic runs once, in the merge, on the merged
    # operands — identical values in identical reduction order.
    # ------------------------------------------------------------------
    def _na_partitioned_own(self, params: Dict, batch: Dict, h_own: Dict):
        """Owned-rows pre-gather pass: everything partitioned NA can do
        from FP's output alone (depends only on FP — runs concurrently
        with ``halo_exchange``).  Returns the pre-gathered operand pytree
        :meth:`_na_partitioned_merge` consumes."""
        plan, cfg = self.plan, self.cfg
        part = batch["part"]
        t = plan.target
        H = cfg.n_heads
        if plan.na.kind == "gat":
            heads = lambda x: x.reshape(x.shape[0], x.shape[1], H, -1)
            hs_own = heads(h_own[t])  # [K, n, H, Dh]

            def one_part(hs_k, nbr_k):  # nbr_k [P, n, Kd]
                def one_path(pp, nn):
                    e_tab = (hs_k * pp["a_src"]).sum(-1)  # [n, H] EW
                    return (stages.gather_own(hs_k, nn),
                            stages.gather_own(e_tab, nn))

                return jax.vmap(one_path)(params["gat"], nbr_k)

            hn_own, e_own = jax.vmap(one_part)(hs_own, part["nbr"])
            return {"hn": hn_own,  # [K, P, n, Kd, H, Dh]
                    "e": e_own}  # [K, P, n, Kd, H]
        if plan.na.kind == "mean":
            out: Dict = {}
            for key in sorted(part["rels"]):
                nbr, _ = part["rels"][key]
                out["|".join(key)] = jax.vmap(stages.gather_own)(
                    h_own[key[0]], nbr)  # [K, n_d, Kd, D]
            return out
        if plan.na.kind == "instance":
            outs: List = []
            for (nodes, _), types in zip(part["instances"], plan.metapaths):
                outs.append([
                    jax.vmap(stages.gather_own)(
                        h_own[types[j]], nodes[:, :, :, j])
                    for j in range(nodes.shape[3])
                ])  # per position: [K, n, I, D]
            return outs
        raise ValueError(
            f"no partitioned NA split for kind {plan.na.kind!r}")

    def _na_partitioned_merge(self, params: Dict, batch: Dict, h_own: Dict,
                              halos: Dict, pre):
        """Merge pass: where-select the exchanged halo rows into the
        pre-gathered owned operands, then run the untouched aggregation
        math.  Output bitwise equals ``_na_partitioned(params, batch,
        gather_halo(batch, h_own))``."""
        plan, cfg = self.plan, self.cfg
        part = batch["part"]
        t = plan.target
        act = _ACT[plan.na.activation]
        H = cfg.n_heads
        if plan.na.kind == "gat":
            n_own = part["feats"][t].shape[1]
            heads = lambda x: x.reshape(x.shape[0], x.shape[1], H, -1)
            hd = heads(h_own[t])  # [K, n, H, Dh] owned rows ARE the dsts
            hs_halo = heads(halos[t])  # [K, h_max, H, Dh]

            def one_part(hd_k, hh_k, nbr_k, mask_k, hno_k, eo_k):
                def one_path(pp, nn, mm, hno, eo):
                    hn = stages.gather_merge(hno, hh_k, nn, n_own)
                    e_tab_h = (hh_k * pp["a_src"]).sum(-1)  # [h_max, H]
                    e_nbr = stages.gather_merge(eo, e_tab_h, nn, n_own)
                    return stages.gat_aggregate_padded(
                        pp, hd_k, None, None, mm, hn=hn, e_nbr=e_nbr)

                return jax.vmap(one_path)(params["gat"], nbr_k, mask_k,
                                          hno_k, eo_k)

            z = jax.vmap(one_part)(hd, hs_halo, part["nbr"], part["mask"],
                                   pre["hn"], pre["e"])
            z = act(z)  # [K, P, n, H, Dh]
            z = z.reshape(z.shape[0], z.shape[1], z.shape[2], -1)
            return stages.shard(z, BATCH, None, None, None)  # [K, P, n, D]
        if plan.na.kind == "mean":
            if plan.n_layers > 1:
                out: Dict = {"__h__": {ty: h_own[ty]
                                       for ty in part["feats"]}}
            else:
                out = {"__h__": h_own[t]}
            for key in sorted(part["rels"]):
                s = key[0]
                n_own_s = part["feats"][s].shape[1]
                nbr, mask = part["rels"][key]
                hn = jax.vmap(
                    lambda ho, hl, nn: stages.gather_merge(
                        ho, hl, nn, n_own_s)
                )(pre["|".join(key)], halos[s], nbr)
                agg = jax.vmap(
                    lambda nn, mm, hh: stages.mean_aggregate_padded(
                        None, nn, mm, hn=hh)
                )(nbr, mask, hn)  # [K, n_d, D]
                out["|".join(key)] = agg @ params["w_rel"][key]
            return out
        if plan.na.kind == "instance":
            h_tgt = h_own[t]
            h_tgt = h_tgt.reshape(h_tgt.shape[0], h_tgt.shape[1], H, -1)
            outs: List[jax.Array] = []
            for p_i, (nodes, mask), types, pre_i in zip(params["att"],
                                                        part["instances"],
                                                        plan.metapaths, pre):
                k_, n, i, l = nodes.shape
                h_path = jnp.stack([
                    jax.vmap(
                        lambda ho, hl, nn, ty=types[j]: stages.gather_merge(
                            ho, hl, nn, part["feats"][ty].shape[1])
                    )(pre_i[j], halos[types[j]], nodes[:, :, :, j])
                    for j in range(l)
                ], axis=3)  # [K, n, I, L, D]
                h_path = h_path.reshape(k_, n, i, l, H, -1)
                enc = jax.vmap(stages.rotate_encoder)(h_path)
                z = jax.vmap(stages.instance_aggregate,
                             in_axes=(None, 0, 0, 0))(p_i, h_tgt, enc, mask)
                outs.append(act(z).reshape(k_, n, -1))  # [K, n, D]
            return outs
        raise ValueError(
            f"no partitioned NA split for kind {plan.na.kind!r}")

    # ------------------------------------------------------------------
    # Stage 4: Semantic Aggregation
    # ------------------------------------------------------------------
    def _rel_sum(self, params: Dict, h_own: Dict, z: Dict) -> Dict:
        """The rel_sum SA body shared by the single-table and partitioned
        flows: per type, sum the relation aggregates (Reduce) into the
        ``w_self`` self-loop.  ``h_own`` maps type -> its own feature rows
        (``[N_t, D]`` or ``[K, n_t, D]``); ``z`` the NA output dict keyed
        by ``"s|r|d"`` relation strings."""
        h_new: Dict = {}
        for t in sorted(h_own):
            acc = None
            for key, v in z.items():
                if key != "__h__" and key.split("|")[2] == t:
                    acc = v if acc is None else acc + v  # Reduce (sum)
            h_self = h_own[t] @ params["w_self"][t]
            h_new[t] = jax.nn.relu(h_self if acc is None else h_self + acc)
        return h_new

    def sa(self, params: Dict, batch: Dict, z):
        plan = self.plan
        if plan.partition is not None:
            return self._sa_partitioned(params, batch, z)
        if plan.sa.kind == "none":
            return z
        if plan.sa.kind == "rel_sum":
            return self._rel_sum(params, z["__h__"], z)
        # attention; sampled minibatches carry a row-validity mask so the
        # rung padding never shifts the semantic score means
        row_mask = batch.get("row_mask")
        if isinstance(z, tuple):  # fused NA→SA epilogue: (z, pass-1 scores)
            z_stack, wp = z
            if row_mask is not None:
                # the kernel's pass-1 mean ran over every row incl. the
                # rung pads; a pad row is a zero row (all-masked neighbor
                # lists aggregate to 0), so each contributes exactly
                # c = q·tanh(b) to the mean — remove them in closed form:
                # wp_masked = (wp·N − n_pad·c) / n_real.  n_pad == 0 (full
                # batches / exact rungs) leaves wp bitwise unchanged.
                sem = params["sem"]
                c = jnp.tanh(sem["b"]) @ sem["q"]
                n_real = jnp.maximum(row_mask.sum(), 1.0)
                n_pad = row_mask.shape[0] - row_mask.sum()
                wp = wp + n_pad * (wp - c) / n_real
            beta = jax.nn.softmax(wp)  # O(P) softmax
            # pass 2 (combine) is the only remaining full read of z
            return _kops().semantic_combine(z_stack, beta,
                                            use_pallas=plan.na.use_pallas)
        if plan.sa.stacked:
            z = stages.shard(z, *stages.HGNN_STAGE_SPECS["sa_stacked"])
            return semantics.semantic_attention(params["sem"], z, row_mask)
        return semantics.semantic_attention_list(params["sem"], z, row_mask)

    def _sa_partitioned(self, params: Dict, batch: Dict, z):
        """SA on the partition-local stacks.  Attention reduces per-partition
        score partials to the global masked mean (a [K, P] reduce is the only
        communication); rel_sum is fully partition-local."""
        plan = self.plan
        part = batch["part"]
        mask = part["own_mask"][plan.target]  # [K, n]
        if plan.sa.kind == "rel_sum":
            if plan.n_layers > 1:
                # every type updates (as in the unpartitioned rel_sum);
                # pad rows stay zero: zero feats -> zero aggregates -> relu(0)
                return self._rel_sum(params, z["__h__"], z)
            # single layer: __h__ is the owned target rows [K, n, D] only
            return self._rel_sum(params, {plan.target: z["__h__"]},
                                 z)[plan.target]
        # attention (HAN stacked [K, P, n, D]; MAGNN list of [K, n, D])
        if isinstance(z, list):
            z = jnp.stack(z, axis=1)  # [K, P, n, D]
        return semantics.semantic_attention_partitioned(
            params["sem"], z, mask)  # [K, n, D]

    # ------------------------------------------------------------------
    # head + forward
    # ------------------------------------------------------------------
    def head(self, params: Dict, z, batch: Dict = None) -> jax.Array:
        plan = self.plan
        w = params[plan.head.param]
        if plan.partition is not None:
            # SA already reduced to the owned target rows [K, n, D] (the
            # multi-layer rel_sum returns every type — select the target);
            # classify locally, then invert the ownership permutation back
            # to global node order (`inv` maps global row -> own-order slot).
            if isinstance(z, dict):
                z = z[plan.target]
            out = z @ w  # [K, n, C]
            flat = out.reshape(-1, out.shape[-1])
            return flat[batch["part"]["inv"]]
        if plan.head.kind == "select_linear":
            return z[plan.head.target] @ w
        return z @ w

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        """The L-layer loop: per-type feature tables are the carried state;
        layer 0 reads the prepared batch, hidden layers the previous
        handoff.  The partitioned flow re-exchanges the *updated* halo
        features every layer over the graph-invariant halo maps."""
        plan = self.plan
        state = out = None
        for l, lp in enumerate(plan.layers):
            p_l = self._layer_params(params, l)
            h = (self.fp(params, batch) if l == 0
                 else self._fp_hidden(lp, p_l, state))
            if plan.partition is not None:
                h = self.gather_halo(batch, h)
            z = self.na(p_l, batch, h)
            out = self.sa(p_l, batch, z)
            if l + 1 < plan.n_layers:
                state = self._handoff(lp, batch, h, out)
        return self.head(params, out, batch)

    # ------------------------------------------------------------------
    # the async stage-graph schedule (plan.schedule)
    # ------------------------------------------------------------------
    def _split_halo(self) -> bool:
        """Does the schedule split partitioned NA into own/halo passes?"""
        s = self.plan.schedule
        return (s is not None and s.overlap_halo
                and self.plan.partition is not None)

    def _split_metapaths(self) -> bool:
        """Does the schedule dispatch per-metapath NA stages?  Only where
        the serial path already loops metapaths (bucketed / csr GAT,
        MAGNN instances) — the stacked layout is ONE launch by design,
        and a single metapath has nothing to overlap."""
        plan, s = self.plan, self.plan.schedule
        return (s is not None and s.overlap_metapaths
                and plan.partition is None
                and len(plan.metapaths) > 1
                and ((plan.na.kind == "gat"
                      and plan.na.layout in ("csr", "bucketed"))
                     or plan.na.kind == "instance"))

    def _sa_entry(self, p_l: Dict, batch: Dict, z):
        """SA entry for the schedule driver: per-metapath NA stages hand
        SA a list; stacked-SA plans re-stack it here.  Activation already
        applied per metapath (elementwise) — stack-after-act is bitwise
        equal to the serial act-after-stack."""
        if self._split_metapaths() and self.plan.sa.stacked:
            z = jnp.stack(z)  # [P, N, D]
        return self.sa(p_l, batch, z)

    def schedule_edges(self) -> Dict[str, Tuple[str, ...]]:
        """The plan-derived dependency-edge table: stage name → the stages
        it must wait for, in topological order.  Purely declarative — the
        driver, the accounting, and the tests all read the same DAG.
        Nodes match the schedule's dispatch granularity: the partitioned
        split runs ``gather_halo`` (exchange only) and ``NA.own``
        concurrently, merging in ``NA``; the metapath split fans ``FP``
        out into ``NA.p{i}`` stages that join at ``SA``."""
        plan = self.plan
        edges: Dict[str, Tuple[str, ...]] = {}
        prev = None
        for l in range(plan.n_layers):
            pre = f"L{l + 1}." if plan.n_layers > 1 else ""
            edges[pre + "FP"] = (prev,) if prev else ()
            if plan.partition is not None:
                edges[pre + "gather_halo"] = (pre + "FP",)
                if self._split_halo():
                    edges[pre + "NA.own"] = (pre + "FP",)
                    edges[pre + "NA"] = (pre + "NA.own", pre + "gather_halo")
                else:
                    edges[pre + "NA"] = (pre + "gather_halo",)
                sa_deps: Tuple[str, ...] = (pre + "NA",)
            elif self._split_metapaths():
                names = [pre + f"NA.p{i}"
                         for i in range(len(plan.metapaths))]
                for nm in names:
                    edges[nm] = (pre + "FP",)
                sa_deps = tuple(names)
            else:
                edges[pre + "NA"] = (pre + "FP",)
                sa_deps = (pre + "NA",)
            edges[pre + "SA"] = sa_deps
            prev = pre + "SA"
        edges["head"] = (prev,)
        return edges

    def overlap_record(self) -> Dict:
        """Deterministic schedule counters (no walls): DAG size and the
        path-independent stage pairs — the concurrency the schedule can
        exploit.  Pinned by CI greps and gated at exact equality by the
        bench; the measured critical-path/exposure accounting lives in
        ``characterize.overlap_accounting``."""
        edges = self.schedule_edges()
        names = list(edges)
        anc: Dict[str, set] = {}
        for n in names:  # topological by construction
            a = set()
            for d in edges[n]:
                a.add(d)
                a |= anc[d]
            anc[n] = a
        pairs = [(a, b) for i, a in enumerate(names) for b in names[i + 1:]
                 if a not in anc[b] and b not in anc[a]]
        sched = self.plan.schedule
        return {
            "depth": sched.depth if sched is not None else 1,
            "stages": len(names),
            "edges": sum(len(d) for d in edges.values()),
            "concurrent_pairs": len(pairs),
            "overlapped_stages": len({s for p in pairs for s in p}),
            "pairs": [f"{a}|{b}" for a, b in pairs],
        }

    def _ovjit(self, key: str, fn):
        f = self._ov_jit.get(key)
        if f is None:
            f = self._ov_jit[key] = jax.jit(fn)
        return f

    def _walk_schedule(self, params: Dict, batch: Dict, emit):
        """Walk the stage DAG in topological order, dispatching each stage
        through ``emit(name, key, fn, args) -> value``.  ``name`` is the
        per-layer stage name (matches :meth:`schedule_edges`); ``key`` the
        jit-cache identity (layer-indexed — stage shapes repeat across
        calls, not across layers with different param trees).  Both the
        async driver and the characterization hook walk this one graph."""
        plan = self.plan
        n_l = plan.n_layers
        state = out = None
        for l, lp in enumerate(plan.layers):
            pre = f"L{l + 1}." if n_l > 1 else ""
            if l == 0:
                h = emit(pre + "FP", "FP0",
                         lambda p, b: self.fp(p, b), (params, batch))
            else:
                h = emit(pre + "FP", f"FP{l}",
                         lambda p, s, lp=lp, l=l: self._fp_hidden(
                             lp, self._layer_params(p, l), s),
                         (params, state))
            if plan.partition is not None:
                if self._split_halo():
                    # the exchange and the owned-rows pre-gather both
                    # depend only on FP — the window dispatches them
                    # back-to-back and they run concurrently
                    halos = emit(pre + "gather_halo", "halo_exchange",
                                 lambda b, hh: self.halo_exchange(b, hh),
                                 (batch, h))
                    pre_g = emit(pre + "NA.own", f"NA.own{l}",
                                 lambda p, b, hh, l=l:
                                 self._na_partitioned_own(
                                     self._layer_params(p, l), b, hh),
                                 (params, batch, h))
                    z = emit(pre + "NA", f"NA.merge{l}",
                             lambda p, b, hh, ha, pg, l=l:
                             self._na_partitioned_merge(
                                 self._layer_params(p, l), b, hh, ha, pg),
                             (params, batch, h, halos, pre_g))
                else:
                    h = emit(pre + "gather_halo", "gather_halo",
                             lambda b, hh: self.gather_halo(b, hh),
                             (batch, h))
                    z = emit(pre + "NA", f"NA{l}",
                             lambda p, b, hh, l=l: self.na(
                                 self._layer_params(p, l), b, hh),
                             (params, batch, h))
            elif self._split_metapaths():
                z = [emit(pre + f"NA.p{i}", f"NA.p{l}.{i}",
                          lambda p, b, hh, l=l, i=i: self._na_metapath(
                              self._layer_params(p, l), b, hh, i),
                          (params, batch, h))
                     for i in range(len(plan.metapaths))]
            else:
                z = emit(pre + "NA", f"NA{l}",
                         lambda p, b, hh, l=l: self.na(
                             self._layer_params(p, l), b, hh),
                         (params, batch, h))
            out = emit(pre + "SA", f"SA{l}",
                       lambda p, b, zz, l=l: self._sa_entry(
                           self._layer_params(p, l), b, zz),
                       (params, batch, z))
            if l + 1 < n_l:
                # host-level repackaging (slices are identities on the
                # own-only tables) — not a schedulable stage
                state = self._handoff(lp, batch, h, out)
        return emit("head", "head",
                    lambda p, b, oo: self.head(p, oo, b),
                    (params, batch, out))

    def forward_overlapped(self, params: Dict, batch: Dict) -> jax.Array:
        """``forward``'s layer loop re-expressed over the plan-derived
        stage DAG: each stage is its own jitted call; the host races ahead
        issuing dependents and blocks only when more than
        ``plan.schedule.depth`` stage results are in flight
        (``kernels.streaming.InflightWindow`` — the DMA double-buffer
        discipline at stage granularity), so JAX's async dispatch runs
        independent stages' device work concurrently.  Bit-exact vs the
        serial schedule: the split stages are pure row selections /
        elementwise rearrangements; depth=1 degrades to fully blocking
        dispatch.  Not itself jit-able (it *is* the dispatcher); the
        per-stage jits are cached on the executor, so repeated calls
        re-trace nothing."""
        from repro.kernels.streaming import InflightWindow

        sched = self.plan.schedule
        win = InflightWindow(sched.depth if sched is not None else 1)

        def emit(name, key, fn, args):
            return win.admit(name, self._ovjit(key, fn)(*args))

        out = self._walk_schedule(params, batch, emit)
        win.drain()
        self.last_dispatch = {
            "dispatched": list(win.admitted),
            "max_inflight": win.max_inflight,
            "depth": win.depth,
        }
        return out

    # ------------------------------------------------------------------
    # per-stage characterization hooks
    # ------------------------------------------------------------------
    def stage_fns(self, params: Dict, batch: Dict) -> Dict[str, Tuple]:
        """Jitted per-stage callables chained on concrete intermediates —
        the separate jit per stage mirrors DGL's separate kernel launches
        and exposes the NA→SA barrier (paper Fig. 5c).

        Single-layer plans keep the historical unprefixed stage names
        (``FP``/``gather_halo``/``NA``/``SA``/``head``); an L-layer stack
        prefixes every per-layer stage with ``L{i}.`` (1-based), so the
        characterization handbook can show depth scaling per layer."""
        plan = self.plan
        n_l = plan.n_layers
        fns: Dict[str, Tuple] = {}
        state = out = None
        # one jitted exchange shared by every layer (same computation on
        # same-shaped tables — a per-layer lambda would recompile it L times)
        gh = (jax.jit(lambda hh: self.gather_halo(batch, hh))
              if plan.partition is not None else None)
        for l, lp in enumerate(plan.layers):
            pre = f"L{l + 1}." if n_l > 1 else ""
            if l == 0:
                fp = jax.jit(lambda p: self.fp(p, batch))
                fp_args: Tuple = (params,)
            else:
                fp = jax.jit(lambda p, s, lp=lp, l=l: self._fp_hidden(
                    lp, self._layer_params(p, l), s))
                fp_args = (params, state)
            h = fp(*fp_args)
            fns[pre + "FP"] = (fp, fp_args)
            if gh is not None:
                fns[pre + "gather_halo"] = (gh, (h,))
                h = gh(h)
            na = jax.jit(lambda p, hh, l=l: self.na(
                self._layer_params(p, l), batch, hh))
            z = na(params, h)
            fns[pre + "NA"] = (na, (params, h))
            sa = jax.jit(lambda p, zz, l=l: self.sa(
                self._layer_params(p, l), batch, zz))
            out = sa(params, z)
            fns[pre + "SA"] = (sa, (params, z))
            if l + 1 < n_l:
                state = self._handoff(lp, batch, h, out)
        head = jax.jit(lambda p, oo: self.head(p, oo, batch))
        fns["head"] = (head, (params, out))
        return fns

    def overlap_stage_fns(self, params: Dict, batch: Dict) -> Dict[str, Tuple]:
        """Overlap-granular analogue of :meth:`stage_fns`: one jitted
        callable per node of :meth:`schedule_edges`, chained on concrete
        intermediates.  The benches time each stage's wall and feed the
        DAG + walls to ``characterize.overlap_accounting`` (critical-path
        vs serial-sum, per-stage exposure)."""
        fns: Dict[str, Tuple] = {}

        def emit(name, key, fn, args):
            f = jax.jit(fn)
            fns[name] = (f, args)
            return f(*args)

        self._walk_schedule(params, batch, emit)
        return fns

    def stage_records(self, params: Dict, batch: Dict,
                      n_chips: int = 1, sample_meta: Dict = None) -> Dict:
        """Per-stage characterization: stage name → FLOPs / HBM bytes /
        roofline terms via ``core/characterize.py``, from the exact stage
        functions the executor serves.  ``total`` is the stage-additive sum
        (the fully-jitted forward may fuse across stage boundaries, so the
        per-stage attribution is the meaningful decomposition).

        ``sample_meta`` (request-path serving): the sampler's host-side
        batch metadata; adds the SAMPLE stage — the paper taxonomy's
        Subgraph Build, realized as the neighbor-sampling gather — as the
        first record (``characterize.sample_traffic``), with its traffic
        kept out of the compiled-stage ``total``."""
        from repro.core.characterize import (analyze_hlo_text,
                                             partition_traffic,
                                             residency_record, roofline,
                                             sample_traffic)

        fns = self.stage_fns(params, batch)
        recs: Dict[str, Dict] = {}
        if sample_meta is not None:
            recs["SAMPLE"] = sample_traffic(sample_meta)
        for name, (fn, args) in fns.items():
            rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
            recs[name] = {
                "flops": rep["total_flops"],
                "hbm_bytes": rep["total_hbm_bytes"],
                "flops_by_class": rep["flops_by_class"],
                "hbm_bytes_by_class": rep["hbm_bytes_by_class"],
                "roofline": roofline(rep, n_chips, 0.0),
            }
        res = batch.get("residency")
        rr = None
        if res is not None:
            # residency accounting: the HLO walker charges every gather at
            # its structural size, so the cache's effect — hot rows served
            # from the resident section instead of re-read from HBM — is
            # applied from the deterministic hit counters.  The hot set is
            # layer-invariant, so only the first cached stage pays the
            # cache fill (HiHGNN inter-layer reuse); hot-halo savings land
            # on the gather_halo records, NA savings on the NA records.
            cached = [n for n in fns if n.endswith(
                "gather_halo" if self.plan.partition is not None else "NA")]
            rr = residency_record(res["counters"], 4 * self.cfg.hidden,
                                  layers=len(cached))
            for i, name in enumerate(cached):
                saved = rr["bytes_saved_per_layer"] - (
                    rr["fill_bytes"] if i == 0 else 0)
                recs[name]["residency_bytes_saved"] = saved
                recs[name]["hit_rate"] = rr["hit_rate"]
                recs[name]["hbm_bytes"] = max(
                    recs[name]["hbm_bytes"] - saved, 0)
        total = {  # compiled stages only — SAMPLE is a host-side gather
            "flops": sum(recs[n]["flops"] for n in fns),
            "hbm_bytes": sum(recs[n]["hbm_bytes"] for n in fns),
        }
        out = {"stages": recs, "total": total}
        if rr is not None:
            out["residency"] = rr
        if self.plan.schedule is not None:
            # schedule accounting: the DAG's deterministic counters (the
            # measured critical-path walls ride the overlap bench, not the
            # HLO records)
            out["overlap"] = self.overlap_record()
        gh_names = [n for n in fns if n.endswith("gather_halo")]
        if gh_names:
            # the communication stage's paper-facing metrics: exchanged halo
            # rows/bytes and the partitioner's cut, from the batch metadata
            # plus the actual per-type feature shapes entering the exchange.
            # Every layer re-exchanges the updated features over the same
            # graph-invariant halo maps, so each per-layer stage gets its
            # own record and the summary reports halo-bytes × L.
            for name in gh_names:
                tr = partition_traffic(batch["part"], fns[name][1][0])
                recs[name]["halo_bytes"] = tr["halo_bytes"]
                recs[name]["cut_edges"] = tr["cut_edges"]
            out["partition"] = partition_traffic(
                batch["part"], fns[gh_names[0]][1][0], layers=len(gh_names))
            if rr is not None:
                # hot halo rows skip the exchange on every layer's re-run
                out["partition"]["halo_bytes_saved_total"] = (
                    rr["bytes_saved_total"])
        return out


class PlannedModel:
    """Base for the model zoo: host-side ``prepare()`` + a ``plan()``
    builder; every device-side stage delegates to the shared executor."""

    def __init__(self, cfg):
        self.cfg = cfg

    def plan(self) -> StagePlan:
        raise NotImplementedError

    @property
    def executor(self) -> StageGraphExecutor:
        ex = self.__dict__.get("_executor")
        if ex is None:
            ex = self.__dict__["_executor"] = StageGraphExecutor(
                self.plan(), self.cfg)
        return ex

    def prepare(self, hg) -> Dict:
        raise NotImplementedError

    def _maybe_partition(self, batch: Dict) -> Dict:
        """End-of-``prepare`` finalize hook: compute the residency hot sets
        from the *unpartitioned* tables (degree ordering is a global-graph
        property), rewrite the batch into the partitioned layout when the
        plan declares one (``repro.dist.partition``), then apply/attach the
        residency tables — single-device batches get their index tables
        remapped into the cache-extended pool, partitioned batches get the
        hot-halo overlay maps."""
        plan = self.plan()
        tables = None
        if plan.residency is not None:
            from repro.core import residency as _rsd

            tables = _rsd.build_tables(plan, batch)
        if plan.partition is not None:
            from repro.dist.partition import partition_batch

            batch = partition_batch(plan, batch)
            if tables is not None:
                batch["residency"] = _rsd.partition_overlay(tables, batch)
            return batch
        if tables is not None:
            batch = _rsd.apply(plan, batch, tables)
        return batch

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        return self.executor.init(rng, batch)

    def fp(self, params: Dict, batch: Dict):
        return self.executor.fp(params, batch)

    def na(self, params: Dict, batch: Dict, h):
        return self.executor.na(params, batch, h)

    def sa(self, params: Dict, batch: Dict, z):
        return self.executor.sa(params, batch, z)

    def head(self, params: Dict, z, batch: Dict = None):
        return self.executor.head(params, z, batch)

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        return self.executor.forward(params, batch)

    def forward_overlapped(self, params: Dict, batch: Dict) -> jax.Array:
        return self.executor.forward_overlapped(params, batch)

    def stage_records(self, params: Dict, batch: Dict, n_chips: int = 1):
        return self.executor.stage_records(params, batch, n_chips=n_chips)
