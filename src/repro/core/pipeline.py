"""The stage-graph executor: one interpreter for every :class:`StagePlan`.

Model classes used to own the dispatch ladder (baseline CSR vs fused
resident vs streaming vs bucketed vs sharded vs pallas-vs-ref) — three
copies of it, one per HGNN.  Here it lives once: the executor resolves
layout, kernel dispatch, sharding constraints and interpret/pallas mode from
the plan, and the models shrink to host-side ``prepare()`` plus a plan
builder (:class:`PlannedModel`).

The executor also owns the paper's two structural optimizations:

* **Graph-partitioned execution** (``plan.partition``): the vertex/feature
  tables are split into K edge-cut partitions (``repro.dist.partition``);
  FP and NA run per-partition on local shards and the halo feature exchange
  between them is an explicit ``gather_halo`` stage (shard_map over the
  BATCH axes when the mesh divides K).  SA runs unchanged on the
  partition-local stacks — its score pass reduces per-partition partials,
  so the only other communication is a [K, P]-sized reduce.

* **Fused NA→SA epilogue** (``plan.sa.fuse_epilogue``): on the stacked
  layout the semantic-score pass-1 partial (``mean_n q·tanh(z W + b)``)
  accumulates inside the NA kernel while each ``z`` tile is in VMEM —
  one full ``[P, N, D]`` HBM read disappears, and SA degenerates to a
  softmax over ``P`` plus the weighted combine (exactly one ``z`` read).
* **Per-stage characterization records** (:meth:`stage_records`): every
  stage function is lowered and walked by ``core/characterize.py``, so
  benchmarks report the paper's Fig. 3-style breakdown from the same code
  path that serves traffic.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semantics, stages
from repro.core.plan import StagePlan
from repro.dist.sharding import BATCH, MODEL

_ACT = {None: lambda x: x, "elu": jax.nn.elu, "relu": jax.nn.relu}


def _kops():
    """Kernel dispatch goes through the module attribute so tests can
    monkeypatch wrappers into interpret mode."""
    from repro.kernels import ops

    return ops


class StageGraphExecutor:
    """Executes a :class:`StagePlan` over a prepared device batch."""

    def __init__(self, plan: StagePlan, cfg):
        self.plan = plan
        self.cfg = cfg

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg, plan = self.cfg, self.plan
        d = cfg.hidden
        if plan.na.kind == "gcn":
            k1, k2 = jax.random.split(rng)
            d_in = batch["feat_dim"]
            return {
                "w1": jax.random.normal(k1, (d_in, d), jnp.float32) / np.sqrt(d_in),
                "w2": jax.random.normal(k2, (d, cfg.n_classes), jnp.float32)
                / np.sqrt(d),
            }
        k_fp, k_na, k_sem, k_cls = jax.random.split(rng, 4)
        params: Dict = {
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }
        head_dim = d // cfg.n_heads
        if plan.na.kind == "gat":
            keys = jax.random.split(k_na, len(plan.metapaths))
            gat = [stages.init_gat(k, cfg.n_heads, head_dim) for k in keys]
            if plan.na.layout == "stacked":
                # one stacked param set -> ONE kernel launch for the stack
                # (bucketed keeps the per-metapath list: no uniform K)
                gat = jax.tree.map(lambda *xs: jnp.stack(xs), *gat)
            params["gat"] = gat
            params["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "instance":
            keys = jax.random.split(k_na, len(plan.metapaths))
            params["att"] = [
                stages.init_instance_attention(k, cfg.n_heads, head_dim)
                for k in keys
            ]
            params["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "mean":
            rel_keys = sorted(batch["rels"])
            rel_ks = jax.random.split(k_na, max(len(rel_keys), 1))
            self_ks = jax.random.split(k_sem, len(batch["counts"]))
            params["w_rel"] = {
                key: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for key, k in zip(rel_keys, rel_ks)
            }
            params["w_self"] = {
                t: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for t, k in zip(sorted(batch["counts"]), self_ks)
            }
        return params

    # ------------------------------------------------------------------
    # Stage 2: Feature Projection
    # ------------------------------------------------------------------
    def fp(self, params: Dict, batch: Dict):
        plan = self.plan
        if plan.partition is not None:
            return self._fp_partitioned(params, batch)
        if plan.fp.kind == "dense":
            return batch["x"] @ params["w1"]
        project = (stages.feature_projection_sharded if plan.fp.sharded
                   else stages.feature_projection)
        h = project(params["fp"], batch["feats"])
        if plan.fp.heads:
            ht = h[plan.target]
            return ht.reshape(ht.shape[0], self.cfg.n_heads, -1)  # [N, H, Dh]
        return h

    def _fp_partitioned(self, params: Dict, batch: Dict) -> Dict:
        """FP over the per-partition owned feature shards: [K, n_t, F_t] @
        W_t per type — pure data parallelism over the partition dim."""
        plan = self.plan
        out: Dict = {}
        for t, f in batch["part"]["feats"].items():
            w = params["fp"][t]
            if plan.fp.sharded:
                w = stages.shard(w, *stages.HGNN_STAGE_SPECS["fp_weight"])
            out[t] = stages.shard(f @ w, BATCH, None, MODEL)
        return out

    # ------------------------------------------------------------------
    # partitioned flow: the halo feature exchange (the new explicit stage)
    # ------------------------------------------------------------------
    def gather_halo(self, batch: Dict, h_own: Dict):
        """Fetch each type's halo rows from the other partitions' owned
        tables and append them: local source table = concat(own, halo).
        The one communication step of the partitioned flow (shard_map
        all-gather on a dividing mesh; see ``repro.dist.partition``)."""
        from repro.dist.partition import gather_halo as _gather

        part = batch["part"]
        mode = self.plan.partition.halo
        out: Dict = {}
        for t, h in h_own.items():
            halo = _gather(h, part["halo_src"][t], mode=mode)
            out[t] = jnp.concatenate([h, halo], axis=1)
        return out

    # ------------------------------------------------------------------
    # Stage 3: Neighbor Aggregation
    # ------------------------------------------------------------------
    def na(self, params: Dict, batch: Dict, h):
        kind = self.plan.na.kind
        if self.plan.partition is not None:
            return self._na_partitioned(params, batch, h)
        if kind == "gat":
            return self._na_gat(params, batch, h)
        if kind == "mean":
            return self._na_mean(params, batch, h)
        if kind == "instance":
            return self._na_instance(params, batch, h)
        if kind == "gcn":
            # both GCN aggregation layers are NA work (the paper's GNN
            # comparison has no semantic stage); the segment count comes
            # from h's static shape so the forward stays jit-able with the
            # batch as an argument (batch["n_nodes"] would be a tracer)
            z = jax.nn.relu(stages.mean_aggregate_csr(
                h, batch["seg"], batch["idx"], h.shape[0]))
            return stages.mean_aggregate_csr(
                z, batch["seg"], batch["idx"], z.shape[0])
        raise ValueError(f"unknown NA kind {kind!r}")

    def _na_gat(self, params: Dict, batch: Dict, h: jax.Array):
        plan, cfg = self.plan, self.cfg
        act = _ACT[plan.na.activation]
        if plan.na.layout == "csr":
            # baseline: independent kernels per subgraph (paper Fig. 5c).
            # h [N, H, Dh] covers the target nodes, so its static leading
            # dim is the segment count (jit-safe: batch["n_nodes"] traces).
            outs: List[jax.Array] = []
            for p_i, (seg, idx) in zip(params["gat"], batch["edges"]):
                z = stages.gat_aggregate_csr(p_i, h, h, seg, idx, h.shape[0])
                outs.append(act(z).reshape(z.shape[0], -1))
            return outs  # list of [N, D]
        if plan.na.layout == "bucketed":
            agg_fn = None
            if plan.na.use_pallas:
                kops = _kops()
                agg_fn = lambda p, hd, hs, nn, mm: kops.gat_aggregate(
                    p, hd, hs, nn, mm, use_pallas=True)
            z = jnp.stack([
                stages.gat_aggregate_bucketed(p_i, h, h, bks, agg_fn=agg_fn)
                for p_i, bks in zip(params["gat"], batch["buckets"])
            ])  # [P, N, H, Dh]
            z = act(z)
            return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]
        # stacked layout: ONE launch for the whole [P, N, K] stack
        if plan.sa.fuse_epilogue:
            return self._na_gat_fused_sa(params, batch, h)
        stacked_fn = None
        if plan.na.use_pallas:
            kops = _kops()
            stacked_fn = lambda pp, hd, hs, nn, mm: kops.gat_aggregate_stacked(
                pp, hd, hs, nn, mm, use_pallas=True)
        z = stages.gat_aggregate_padded_stacked(
            params["gat"], h, batch["nbr"], batch["mask"],
            stacked_fn=stacked_fn)
        z = act(z)
        return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]

    def _na_gat_fused_sa(self, params: Dict, batch: Dict, h: jax.Array):
        """Stacked NA with the SA pass-1 epilogue fused in: returns
        ``(z [P, N, D] activation applied, wp [P] semantic-score means)``."""
        if self.plan.na.activation != "elu":
            # the kernel epilogue bakes the NA activation in (elu); a plan
            # declaring another activation would silently diverge
            raise ValueError("sa.fuse_epilogue requires na.activation='elu' "
                             f"(got {self.plan.na.activation!r})")
        kops = _kops()
        specs = stages.HGNN_STAGE_SPECS
        h_src = stages.shard(h, *specs["na_src"])
        nbr = stages.shard(batch["nbr"], None, *specs["na_nbr"])
        mask = stages.shard(batch["mask"], None, *specs["na_nbr"])
        z4, wp = kops.gat_aggregate_stacked_fused_sa(
            params["gat"], h, h_src, nbr, mask, params["sem"],
            use_pallas=self.plan.na.use_pallas)
        z4 = stages.shard(z4, None, *specs["na_out"])
        return z4.reshape(z4.shape[0], z4.shape[1], -1), wp

    def _na_mean(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        plan = self.plan
        # "__h__" rides along for the self-loop term in SA (rel_sum)
        out: Dict = {"__h__": h}
        agg_fn = None
        if plan.na.use_pallas and plan.na.layout != "csr":
            kops = _kops()
            agg_fn = lambda hs, nn, mm: kops.segment_spmm(
                hs, nn, mm, mean=True, use_pallas=True)
        for key in sorted(batch["rels"]):
            s, r, d = key
            rel = batch["rels"][key]
            if plan.na.layout == "csr":
                # h[d]'s static leading dim is the destination-type count
                # (jit-safe: batch["counts"] values trace)
                agg = stages.mean_aggregate_csr(h[s], rel[0], rel[1],
                                                h[d].shape[0])
            elif plan.na.layout == "bucketed":
                # bucket row_ids partition the destination rows, so the row
                # count is static even when batch["counts"] rides a tracer
                n_rows = sum(b[0].shape[0] for b in rel)
                agg = stages.mean_aggregate_bucketed(
                    h[s], rel, n_rows, agg_fn=agg_fn)
            else:  # padded
                agg = stages.mean_aggregate_padded_sharded(
                    h[s], rel[0], rel[1], agg_fn=agg_fn)
            out["|".join(key)] = agg @ params["w_rel"][key]
        return out

    def _na_instance(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        plan, cfg = self.plan, self.cfg
        specs = stages.HGNN_STAGE_SPECS
        H = cfg.n_heads
        act = _ACT[plan.na.activation]
        outs: List[jax.Array] = []
        for p_i, (nodes, mask), types in zip(params["att"],
                                             batch["instances"],
                                             plan.metapaths):
            nodes = stages.shard(nodes, *specs["na_inst_nodes"])
            mask = stages.shard(mask, *specs["na_nbr"])
            n, i, l = nodes.shape
            # gather projected features per path position (types are static,
            # carried by the plan)
            h_path = jnp.stack(
                [h[types[j]][nodes[:, :, j]] for j in range(l)], axis=2
            )  # [N, I, L, D]
            h_path = h_path.reshape(n, i, l, H, -1)
            enc = stages.rotate_encoder(h_path)  # [N, I, H, Dh]
            h_tgt = h[plan.target].reshape(-1, H, h_path.shape[-1])
            if plan.na.use_pallas:
                # Instance attention IS padded GAT NA with the encoded
                # instances as the source pool (arange neighbor grid).
                kops = _kops()
                flat = enc.reshape(n * i, H, enc.shape[-1])
                nbr_inst = jnp.arange(n * i, dtype=jnp.int32).reshape(n, i)
                z = kops.gat_aggregate(p_i, h_tgt, flat, nbr_inst, mask,
                                       use_pallas=True)
            else:
                z = stages.instance_aggregate(p_i, h_tgt, enc, mask)
            z = act(z).reshape(n, -1)
            outs.append(stages.shard(z, *specs["na_flat_out"]))  # [N, D]
        return outs

    def _na_partitioned(self, params: Dict, batch: Dict, h_loc: Dict):
        """NA over partition-local shards: destinations are the owned rows,
        sources the concat(own, halo) local tables built by ``gather_halo``.
        Runs the XLA padded path vmapped over the partition dim (fusing the
        Pallas kernels into the per-partition body is future work)."""
        plan, cfg = self.plan, self.cfg
        part = batch["part"]
        t = plan.target
        act = _ACT[plan.na.activation]
        H = cfg.n_heads
        if plan.na.kind == "gat":
            n_own = part["feats"][t].shape[1]
            heads = lambda x: x.reshape(x.shape[0], x.shape[1], H, -1)
            hd = heads(h_loc[t][:, :n_own])  # [K, n, H, Dh] owned rows
            hs = heads(h_loc[t])  # [K, n+halo, H, Dh] local source pool

            def one_part(hd_k, hs_k, nbr_k, mask_k):  # nbr_k [P, n, Kd]
                return jax.vmap(
                    lambda pp, nn, mm: stages.gat_aggregate_padded(
                        pp, hd_k, hs_k, nn, mm),
                    in_axes=(0, 0, 0))(params["gat"], nbr_k, mask_k)

            z = jax.vmap(one_part)(hd, hs, part["nbr"], part["mask"])
            z = act(z)  # [K, P, n, H, Dh]
            z = z.reshape(z.shape[0], z.shape[1], z.shape[2], -1)
            return stages.shard(z, BATCH, None, None, None)  # [K, P, n, D]
        if plan.na.kind == "mean":
            out: Dict = {"__h__": h_loc[t][:, : part["feats"][t].shape[1]]}
            for key in sorted(part["rels"]):
                s = key[0]
                nbr, mask = part["rels"][key]
                agg = jax.vmap(stages.mean_aggregate_padded)(
                    h_loc[s], nbr, mask)  # [K, n, D]
                out["|".join(key)] = agg @ params["w_rel"][key]
            return out
        if plan.na.kind == "instance":
            h_tgt = h_loc[t][:, : part["feats"][t].shape[1]]
            h_tgt = h_tgt.reshape(h_tgt.shape[0], h_tgt.shape[1], H, -1)
            outs: List[jax.Array] = []
            for p_i, (nodes, mask), types in zip(params["att"],
                                                 part["instances"],
                                                 plan.metapaths):
                k_, n, i, l = nodes.shape
                h_path = jnp.stack(
                    [jax.vmap(lambda hh, idx: hh[idx])(
                        h_loc[types[j]], nodes[:, :, :, j])
                     for j in range(l)], axis=3)  # [K, n, I, L, D]
                h_path = h_path.reshape(k_, n, i, l, H, -1)
                enc = jax.vmap(stages.rotate_encoder)(h_path)  # [K, n, I, H, Dh]
                z = jax.vmap(stages.instance_aggregate, in_axes=(None, 0, 0, 0))(
                    p_i, h_tgt, enc, mask)
                outs.append(act(z).reshape(k_, n, -1))  # [K, n, D]
            return outs
        raise ValueError(
            f"no partitioned NA path for kind {plan.na.kind!r}")

    # ------------------------------------------------------------------
    # Stage 4: Semantic Aggregation
    # ------------------------------------------------------------------
    def sa(self, params: Dict, batch: Dict, z):
        plan = self.plan
        if plan.partition is not None:
            return self._sa_partitioned(params, batch, z)
        if plan.sa.kind == "none":
            return z
        if plan.sa.kind == "rel_sum":
            h = z["__h__"]
            h_new: Dict[str, jax.Array] = {}
            for t in batch["counts"]:
                acc = None
                for key, v in z.items():
                    if key != "__h__" and key.split("|")[2] == t:
                        acc = v if acc is None else acc + v  # Reduce (sum)
                h_self = h[t] @ params["w_self"][t]
                h_new[t] = jax.nn.relu(h_self if acc is None else h_self + acc)
            return h_new
        # attention
        if isinstance(z, tuple):  # fused NA→SA epilogue: (z, pass-1 scores)
            z_stack, wp = z
            beta = jax.nn.softmax(wp)  # O(P) softmax
            # pass 2 (combine) is the only remaining full read of z
            return _kops().semantic_combine(z_stack, beta,
                                            use_pallas=plan.na.use_pallas)
        if plan.sa.stacked:
            z = stages.shard(z, *stages.HGNN_STAGE_SPECS["sa_stacked"])
            return semantics.semantic_attention(params["sem"], z)
        return semantics.semantic_attention_list(params["sem"], z)

    def _sa_partitioned(self, params: Dict, batch: Dict, z):
        """SA on the partition-local stacks.  Attention reduces per-partition
        score partials to the global masked mean (a [K, P] reduce is the only
        communication); rel_sum is fully partition-local."""
        plan = self.plan
        part = batch["part"]
        mask = part["own_mask"][plan.target]  # [K, n]
        if plan.sa.kind == "rel_sum":
            h = z["__h__"]  # [K, n, D] owned target rows
            acc = None
            for key, v in z.items():
                if key != "__h__" and key.split("|")[2] == plan.target:
                    acc = v if acc is None else acc + v
            h_self = h @ params["w_self"][plan.target]
            return jax.nn.relu(h_self if acc is None else h_self + acc)
        # attention (HAN stacked [K, P, n, D]; MAGNN list of [K, n, D])
        if isinstance(z, list):
            z = jnp.stack(z, axis=1)  # [K, P, n, D]
        return semantics.semantic_attention_partitioned(
            params["sem"], z, mask)  # [K, n, D]

    # ------------------------------------------------------------------
    # head + forward
    # ------------------------------------------------------------------
    def head(self, params: Dict, z, batch: Dict = None) -> jax.Array:
        plan = self.plan
        w = params[plan.head.param]
        if plan.partition is not None:
            # SA already reduced to the owned target rows [K, n, D]; classify
            # locally, then invert the ownership permutation back to global
            # node order (`inv` maps global row -> flat own-order slot).
            out = z @ w  # [K, n, C]
            flat = out.reshape(-1, out.shape[-1])
            return flat[batch["part"]["inv"]]
        if plan.head.kind == "select_linear":
            return z[plan.head.target] @ w
        return z @ w

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.fp(params, batch)
        if self.plan.partition is not None:
            h = self.gather_halo(batch, h)
        z = self.na(params, batch, h)
        return self.head(params, self.sa(params, batch, z), batch)

    # ------------------------------------------------------------------
    # per-stage characterization hooks
    # ------------------------------------------------------------------
    def stage_fns(self, params: Dict, batch: Dict) -> Dict[str, Tuple]:
        """Jitted per-stage callables chained on concrete intermediates —
        the separate jit per stage mirrors DGL's separate kernel launches
        and exposes the NA→SA barrier (paper Fig. 5c)."""
        fp = jax.jit(lambda p: self.fp(p, batch))
        h = fp(params)
        fns: Dict[str, Tuple] = {"FP": (fp, (params,))}
        if self.plan.partition is not None:
            gh = jax.jit(lambda hh: self.gather_halo(batch, hh))
            fns["gather_halo"] = (gh, (h,))
            h = gh(h)
        na = jax.jit(lambda p, hh: self.na(p, batch, hh))
        z = na(params, h)
        sa = jax.jit(lambda p, zz: self.sa(p, batch, zz))
        out = sa(params, z)
        head = jax.jit(lambda p, oo: self.head(p, oo, batch))
        fns.update({"NA": (na, (params, h)), "SA": (sa, (params, z)),
                    "head": (head, (params, out))})
        return fns

    def stage_records(self, params: Dict, batch: Dict,
                      n_chips: int = 1) -> Dict:
        """Per-stage characterization: stage name → FLOPs / HBM bytes /
        roofline terms via ``core/characterize.py``, from the exact stage
        functions the executor serves.  ``total`` is the stage-additive sum
        (the fully-jitted forward may fuse across stage boundaries, so the
        per-stage attribution is the meaningful decomposition)."""
        from repro.core.characterize import (analyze_hlo_text,
                                             partition_traffic, roofline)

        fns = self.stage_fns(params, batch)
        recs: Dict[str, Dict] = {}
        for name, (fn, args) in fns.items():
            rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
            recs[name] = {
                "flops": rep["total_flops"],
                "hbm_bytes": rep["total_hbm_bytes"],
                "flops_by_class": rep["flops_by_class"],
                "hbm_bytes_by_class": rep["hbm_bytes_by_class"],
                "roofline": roofline(rep, n_chips, 0.0),
            }
        total = {
            "flops": sum(r["flops"] for r in recs.values()),
            "hbm_bytes": sum(r["hbm_bytes"] for r in recs.values()),
        }
        out = {"stages": recs, "total": total}
        if "gather_halo" in fns:
            # the communication stage's paper-facing metrics: exchanged halo
            # rows/bytes and the partitioner's cut, from the batch metadata
            # plus the actual per-type feature shapes entering the exchange
            traffic = partition_traffic(batch["part"], fns["gather_halo"][1][0])
            recs["gather_halo"]["halo_bytes"] = traffic["halo_bytes"]
            recs["gather_halo"]["cut_edges"] = traffic["cut_edges"]
            out["partition"] = traffic
        return out


class PlannedModel:
    """Base for the model zoo: host-side ``prepare()`` + a ``plan()``
    builder; every device-side stage delegates to the shared executor."""

    def __init__(self, cfg):
        self.cfg = cfg

    def plan(self) -> StagePlan:
        raise NotImplementedError

    @property
    def executor(self) -> StageGraphExecutor:
        ex = self.__dict__.get("_executor")
        if ex is None:
            ex = self.__dict__["_executor"] = StageGraphExecutor(
                self.plan(), self.cfg)
        return ex

    def prepare(self, hg) -> Dict:
        raise NotImplementedError

    def _maybe_partition(self, batch: Dict) -> Dict:
        """End-of-``prepare`` hook: rewrite the batch into the partitioned
        layout when the plan declares one (``repro.dist.partition``)."""
        plan = self.plan()
        if plan.partition is None:
            return batch
        from repro.dist.partition import partition_batch

        return partition_batch(plan, batch)

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        return self.executor.init(rng, batch)

    def fp(self, params: Dict, batch: Dict):
        return self.executor.fp(params, batch)

    def na(self, params: Dict, batch: Dict, h):
        return self.executor.na(params, batch, h)

    def sa(self, params: Dict, batch: Dict, z):
        return self.executor.sa(params, batch, z)

    def head(self, params: Dict, z, batch: Dict = None):
        return self.executor.head(params, z, batch)

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        return self.executor.forward(params, batch)

    def stage_records(self, params: Dict, batch: Dict, n_chips: int = 1):
        return self.executor.stage_records(params, batch, n_chips=n_chips)
