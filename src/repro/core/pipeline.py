"""The stage-graph executor: one interpreter for every :class:`StagePlan`.

Model classes used to own the dispatch ladder (baseline CSR vs fused
resident vs streaming vs bucketed vs sharded vs pallas-vs-ref) — three
copies of it, one per HGNN.  Here it lives once: the executor resolves
layout, kernel dispatch, sharding constraints and interpret/pallas mode from
the plan, and the models shrink to host-side ``prepare()`` plus a plan
builder (:class:`PlannedModel`).

The executor also owns the paper's two structural optimizations:

* **Fused NA→SA epilogue** (``plan.sa.fuse_epilogue``): on the stacked
  layout the semantic-score pass-1 partial (``mean_n q·tanh(z W + b)``)
  accumulates inside the NA kernel while each ``z`` tile is in VMEM —
  one full ``[P, N, D]`` HBM read disappears, and SA degenerates to a
  softmax over ``P`` plus the weighted combine (exactly one ``z`` read).
* **Per-stage characterization records** (:meth:`stage_records`): every
  stage function is lowered and walked by ``core/characterize.py``, so
  benchmarks report the paper's Fig. 3-style breakdown from the same code
  path that serves traffic.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semantics, stages
from repro.core.plan import StagePlan

_ACT = {None: lambda x: x, "elu": jax.nn.elu, "relu": jax.nn.relu}


def _kops():
    """Kernel dispatch goes through the module attribute so tests can
    monkeypatch wrappers into interpret mode."""
    from repro.kernels import ops

    return ops


class StageGraphExecutor:
    """Executes a :class:`StagePlan` over a prepared device batch."""

    def __init__(self, plan: StagePlan, cfg):
        self.plan = plan
        self.cfg = cfg

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        cfg, plan = self.cfg, self.plan
        d = cfg.hidden
        if plan.na.kind == "gcn":
            k1, k2 = jax.random.split(rng)
            d_in = batch["feat_dim"]
            return {
                "w1": jax.random.normal(k1, (d_in, d), jnp.float32) / np.sqrt(d_in),
                "w2": jax.random.normal(k2, (d, cfg.n_classes), jnp.float32)
                / np.sqrt(d),
            }
        k_fp, k_na, k_sem, k_cls = jax.random.split(rng, 4)
        params: Dict = {
            "fp": stages.init_feature_projection(k_fp, batch["feat_dims"], d),
            "cls": jax.random.normal(k_cls, (d, cfg.n_classes), jnp.float32)
            / np.sqrt(d),
        }
        head_dim = d // cfg.n_heads
        if plan.na.kind == "gat":
            keys = jax.random.split(k_na, len(plan.metapaths))
            gat = [stages.init_gat(k, cfg.n_heads, head_dim) for k in keys]
            if plan.na.layout == "stacked":
                # one stacked param set -> ONE kernel launch for the stack
                # (bucketed keeps the per-metapath list: no uniform K)
                gat = jax.tree.map(lambda *xs: jnp.stack(xs), *gat)
            params["gat"] = gat
            params["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "instance":
            keys = jax.random.split(k_na, len(plan.metapaths))
            params["att"] = [
                stages.init_instance_attention(k, cfg.n_heads, head_dim)
                for k in keys
            ]
            params["sem"] = semantics.init_semantic_attention(
                k_sem, d, cfg.attn_hidden)
        elif plan.na.kind == "mean":
            rel_keys = sorted(batch["rels"])
            rel_ks = jax.random.split(k_na, max(len(rel_keys), 1))
            self_ks = jax.random.split(k_sem, len(batch["counts"]))
            params["w_rel"] = {
                key: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for key, k in zip(rel_keys, rel_ks)
            }
            params["w_self"] = {
                t: jax.random.normal(k, (d, d), jnp.float32) / np.sqrt(d)
                for t, k in zip(sorted(batch["counts"]), self_ks)
            }
        return params

    # ------------------------------------------------------------------
    # Stage 2: Feature Projection
    # ------------------------------------------------------------------
    def fp(self, params: Dict, batch: Dict):
        plan = self.plan
        if plan.fp.kind == "dense":
            return batch["x"] @ params["w1"]
        project = (stages.feature_projection_sharded if plan.fp.sharded
                   else stages.feature_projection)
        h = project(params["fp"], batch["feats"])
        if plan.fp.heads:
            ht = h[plan.target]
            return ht.reshape(ht.shape[0], self.cfg.n_heads, -1)  # [N, H, Dh]
        return h

    # ------------------------------------------------------------------
    # Stage 3: Neighbor Aggregation
    # ------------------------------------------------------------------
    def na(self, params: Dict, batch: Dict, h):
        kind = self.plan.na.kind
        if kind == "gat":
            return self._na_gat(params, batch, h)
        if kind == "mean":
            return self._na_mean(params, batch, h)
        if kind == "instance":
            return self._na_instance(params, batch, h)
        if kind == "gcn":
            # both GCN aggregation layers are NA work (the paper's GNN
            # comparison has no semantic stage); the segment count comes
            # from h's static shape so the forward stays jit-able with the
            # batch as an argument (batch["n_nodes"] would be a tracer)
            z = jax.nn.relu(stages.mean_aggregate_csr(
                h, batch["seg"], batch["idx"], h.shape[0]))
            return stages.mean_aggregate_csr(
                z, batch["seg"], batch["idx"], z.shape[0])
        raise ValueError(f"unknown NA kind {kind!r}")

    def _na_gat(self, params: Dict, batch: Dict, h: jax.Array):
        plan, cfg = self.plan, self.cfg
        act = _ACT[plan.na.activation]
        if plan.na.layout == "csr":
            # baseline: independent kernels per subgraph (paper Fig. 5c).
            # h [N, H, Dh] covers the target nodes, so its static leading
            # dim is the segment count (jit-safe: batch["n_nodes"] traces).
            outs: List[jax.Array] = []
            for p_i, (seg, idx) in zip(params["gat"], batch["edges"]):
                z = stages.gat_aggregate_csr(p_i, h, h, seg, idx, h.shape[0])
                outs.append(act(z).reshape(z.shape[0], -1))
            return outs  # list of [N, D]
        if plan.na.layout == "bucketed":
            agg_fn = None
            if plan.na.use_pallas:
                kops = _kops()
                agg_fn = lambda p, hd, hs, nn, mm: kops.gat_aggregate(
                    p, hd, hs, nn, mm, use_pallas=True)
            z = jnp.stack([
                stages.gat_aggregate_bucketed(p_i, h, h, bks, agg_fn=agg_fn)
                for p_i, bks in zip(params["gat"], batch["buckets"])
            ])  # [P, N, H, Dh]
            z = act(z)
            return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]
        # stacked layout: ONE launch for the whole [P, N, K] stack
        if plan.sa.fuse_epilogue:
            return self._na_gat_fused_sa(params, batch, h)
        stacked_fn = None
        if plan.na.use_pallas:
            kops = _kops()
            stacked_fn = lambda pp, hd, hs, nn, mm: kops.gat_aggregate_stacked(
                pp, hd, hs, nn, mm, use_pallas=True)
        z = stages.gat_aggregate_padded_stacked(
            params["gat"], h, batch["nbr"], batch["mask"],
            stacked_fn=stacked_fn)
        z = act(z)
        return z.reshape(z.shape[0], z.shape[1], -1)  # [P, N, D]

    def _na_gat_fused_sa(self, params: Dict, batch: Dict, h: jax.Array):
        """Stacked NA with the SA pass-1 epilogue fused in: returns
        ``(z [P, N, D] activation applied, wp [P] semantic-score means)``."""
        if self.plan.na.activation != "elu":
            # the kernel epilogue bakes the NA activation in (elu); a plan
            # declaring another activation would silently diverge
            raise ValueError("sa.fuse_epilogue requires na.activation='elu' "
                             f"(got {self.plan.na.activation!r})")
        kops = _kops()
        specs = stages.HGNN_STAGE_SPECS
        h_src = stages.shard(h, *specs["na_src"])
        nbr = stages.shard(batch["nbr"], None, *specs["na_nbr"])
        mask = stages.shard(batch["mask"], None, *specs["na_nbr"])
        z4, wp = kops.gat_aggregate_stacked_fused_sa(
            params["gat"], h, h_src, nbr, mask, params["sem"],
            use_pallas=self.plan.na.use_pallas)
        z4 = stages.shard(z4, None, *specs["na_out"])
        return z4.reshape(z4.shape[0], z4.shape[1], -1), wp

    def _na_mean(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        plan = self.plan
        # "__h__" rides along for the self-loop term in SA (rel_sum)
        out: Dict = {"__h__": h}
        agg_fn = None
        if plan.na.use_pallas and plan.na.layout != "csr":
            kops = _kops()
            agg_fn = lambda hs, nn, mm: kops.segment_spmm(
                hs, nn, mm, mean=True, use_pallas=True)
        for key in sorted(batch["rels"]):
            s, r, d = key
            rel = batch["rels"][key]
            if plan.na.layout == "csr":
                # h[d]'s static leading dim is the destination-type count
                # (jit-safe: batch["counts"] values trace)
                agg = stages.mean_aggregate_csr(h[s], rel[0], rel[1],
                                                h[d].shape[0])
            elif plan.na.layout == "bucketed":
                # bucket row_ids partition the destination rows, so the row
                # count is static even when batch["counts"] rides a tracer
                n_rows = sum(b[0].shape[0] for b in rel)
                agg = stages.mean_aggregate_bucketed(
                    h[s], rel, n_rows, agg_fn=agg_fn)
            else:  # padded
                agg = stages.mean_aggregate_padded_sharded(
                    h[s], rel[0], rel[1], agg_fn=agg_fn)
            out["|".join(key)] = agg @ params["w_rel"][key]
        return out

    def _na_instance(self, params: Dict, batch: Dict, h: Dict[str, jax.Array]):
        plan, cfg = self.plan, self.cfg
        specs = stages.HGNN_STAGE_SPECS
        H = cfg.n_heads
        act = _ACT[plan.na.activation]
        outs: List[jax.Array] = []
        for p_i, (nodes, mask), types in zip(params["att"],
                                             batch["instances"],
                                             plan.metapaths):
            nodes = stages.shard(nodes, *specs["na_inst_nodes"])
            mask = stages.shard(mask, *specs["na_nbr"])
            n, i, l = nodes.shape
            # gather projected features per path position (types are static,
            # carried by the plan)
            h_path = jnp.stack(
                [h[types[j]][nodes[:, :, j]] for j in range(l)], axis=2
            )  # [N, I, L, D]
            h_path = h_path.reshape(n, i, l, H, -1)
            enc = stages.rotate_encoder(h_path)  # [N, I, H, Dh]
            h_tgt = h[plan.target].reshape(-1, H, h_path.shape[-1])
            if plan.na.use_pallas:
                # Instance attention IS padded GAT NA with the encoded
                # instances as the source pool (arange neighbor grid).
                kops = _kops()
                flat = enc.reshape(n * i, H, enc.shape[-1])
                nbr_inst = jnp.arange(n * i, dtype=jnp.int32).reshape(n, i)
                z = kops.gat_aggregate(p_i, h_tgt, flat, nbr_inst, mask,
                                       use_pallas=True)
            else:
                z = stages.instance_aggregate(p_i, h_tgt, enc, mask)
            z = act(z).reshape(n, -1)
            outs.append(stages.shard(z, *specs["na_flat_out"]))  # [N, D]
        return outs

    # ------------------------------------------------------------------
    # Stage 4: Semantic Aggregation
    # ------------------------------------------------------------------
    def sa(self, params: Dict, batch: Dict, z):
        plan = self.plan
        if plan.sa.kind == "none":
            return z
        if plan.sa.kind == "rel_sum":
            h = z["__h__"]
            h_new: Dict[str, jax.Array] = {}
            for t in batch["counts"]:
                acc = None
                for key, v in z.items():
                    if key != "__h__" and key.split("|")[2] == t:
                        acc = v if acc is None else acc + v  # Reduce (sum)
                h_self = h[t] @ params["w_self"][t]
                h_new[t] = jax.nn.relu(h_self if acc is None else h_self + acc)
            return h_new
        # attention
        if isinstance(z, tuple):  # fused NA→SA epilogue: (z, pass-1 scores)
            z_stack, wp = z
            beta = jax.nn.softmax(wp)  # O(P) softmax
            # pass 2 (combine) is the only remaining full read of z
            return _kops().semantic_combine(z_stack, beta,
                                            use_pallas=plan.na.use_pallas)
        if plan.sa.stacked:
            z = stages.shard(z, *stages.HGNN_STAGE_SPECS["sa_stacked"])
            return semantics.semantic_attention(params["sem"], z)
        return semantics.semantic_attention_list(params["sem"], z)

    # ------------------------------------------------------------------
    # head + forward
    # ------------------------------------------------------------------
    def head(self, params: Dict, z) -> jax.Array:
        plan = self.plan
        w = params[plan.head.param]
        if plan.head.kind == "select_linear":
            return z[plan.head.target] @ w
        return z @ w

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        h = self.fp(params, batch)
        z = self.na(params, batch, h)
        return self.head(params, self.sa(params, batch, z))

    # ------------------------------------------------------------------
    # per-stage characterization hooks
    # ------------------------------------------------------------------
    def stage_fns(self, params: Dict, batch: Dict) -> Dict[str, Tuple]:
        """Jitted per-stage callables chained on concrete intermediates —
        the separate jit per stage mirrors DGL's separate kernel launches
        and exposes the NA→SA barrier (paper Fig. 5c)."""
        fp = jax.jit(lambda p: self.fp(p, batch))
        h = fp(params)
        na = jax.jit(lambda p, hh: self.na(p, batch, hh))
        z = na(params, h)
        sa = jax.jit(lambda p, zz: self.sa(p, batch, zz))
        out = sa(params, z)
        head = jax.jit(lambda p, oo: self.head(p, oo))
        return {"FP": (fp, (params,)), "NA": (na, (params, h)),
                "SA": (sa, (params, z)), "head": (head, (params, out))}

    def stage_records(self, params: Dict, batch: Dict,
                      n_chips: int = 1) -> Dict:
        """Per-stage characterization: stage name → FLOPs / HBM bytes /
        roofline terms via ``core/characterize.py``, from the exact stage
        functions the executor serves.  ``total`` is the stage-additive sum
        (the fully-jitted forward may fuse across stage boundaries, so the
        per-stage attribution is the meaningful decomposition)."""
        from repro.core.characterize import analyze_hlo_text, roofline

        recs: Dict[str, Dict] = {}
        for name, (fn, args) in self.stage_fns(params, batch).items():
            rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
            recs[name] = {
                "flops": rep["total_flops"],
                "hbm_bytes": rep["total_hbm_bytes"],
                "flops_by_class": rep["flops_by_class"],
                "hbm_bytes_by_class": rep["hbm_bytes_by_class"],
                "roofline": roofline(rep, n_chips, 0.0),
            }
        total = {
            "flops": sum(r["flops"] for r in recs.values()),
            "hbm_bytes": sum(r["hbm_bytes"] for r in recs.values()),
        }
        return {"stages": recs, "total": total}


class PlannedModel:
    """Base for the model zoo: host-side ``prepare()`` + a ``plan()``
    builder; every device-side stage delegates to the shared executor."""

    def __init__(self, cfg):
        self.cfg = cfg

    def plan(self) -> StagePlan:
        raise NotImplementedError

    @property
    def executor(self) -> StageGraphExecutor:
        ex = self.__dict__.get("_executor")
        if ex is None:
            ex = self.__dict__["_executor"] = StageGraphExecutor(
                self.plan(), self.cfg)
        return ex

    def prepare(self, hg) -> Dict:
        raise NotImplementedError

    def init(self, rng: jax.Array, batch: Dict) -> Dict:
        return self.executor.init(rng, batch)

    def fp(self, params: Dict, batch: Dict):
        return self.executor.fp(params, batch)

    def na(self, params: Dict, batch: Dict, h):
        return self.executor.na(params, batch, h)

    def sa(self, params: Dict, batch: Dict, z):
        return self.executor.sa(params, batch, z)

    def head(self, params: Dict, z):
        return self.executor.head(params, z)

    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        return self.executor.forward(params, batch)

    def stage_records(self, params: Dict, batch: Dict, n_chips: int = 1):
        return self.executor.stage_records(params, batch, n_chips=n_chips)
