"""Graph-partitioned multi-host execution (host-side partitioner + halo exchange).

The paper's dominant stage — Neighbor Aggregation — is bound by irregular
neighbor traffic, which at serving scale means the vertex/feature tables must
be *partitioned* across hosts rather than replicated (HiHGNN, arXiv:2307.12765;
the training characterization, arXiv:2407.11790, shows inter-device neighbor
exchange becoming the bottleneck once graphs outgrow one device).  This module
owns everything the partitioned execution mode needs:

* **Per-type vertex assignment** — a metapath-aware greedy edge-cut
  partitioner (:func:`edge_cut_assign`) for the target type (vertices sharing
  metapath neighbors co-locate, so shared source rows are fetched once), and a
  reference-majority assignment (:func:`reference_assign`) for every other
  gathered type (a vertex lives where most of its readers live).
* **Halo / ghost-vertex index maps** — per partition and per type, the set of
  non-owned vertices its local Neighbor Aggregation reads.  Halos are ragged
  across partitions; they are padded per type to a uniform ``[K, H_max]``
  table of *flat own-order indices* (``owner * n_max + local``) so the halo
  feature exchange is one gather over the stacked owned tables.
* **Per-partition relabeling** — neighbor / relation / instance tables are
  rewritten from global vertex ids into partition-local coordinates
  (``0..n_max-1`` = owned rows, ``n_max..`` = halo rows), so every NA gather
  in the partitioned flow is local to ``concat(own, halo)``.
* **The halo exchange itself** — :func:`gather_halo`: on a mesh whose BATCH
  axes divide ``K`` it runs as an explicit ``shard_map`` over the partition
  dim (``all_gather`` of the owned shards + a local gather — the one
  communication step of the partitioned flow); otherwise it degrades to a
  plain flat gather whose cross-shard traffic XLA resolves from the sharding
  constraints (and which is a no-op resharding-wise off-mesh, so
  single-device parity tests run the exact same math).

``partition_batch`` is the entry point: it post-processes a model's prepared
(unpartitioned) device batch into the partitioned layout declared by
``plan.partition`` (a :class:`repro.core.plan.PartitionSpec`), covering the
``stacked`` (HAN), ``padded`` relational (RGCN) and ``instances`` (MAGNN)
NA layouts.  Everything here except :func:`gather_halo` runs on the host
(numpy) as part of Subgraph Build — exactly where the paper places stage 1.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import BATCH, current_mesh, shard


# ---------------------------------------------------------------------------
# vertex assignment
# ---------------------------------------------------------------------------


def edge_cut_assign(neigh: Sequence[np.ndarray], n_tokens: int,
                    k: int) -> np.ndarray:
    """Greedy streaming edge-cut assignment of ``len(neigh)`` vertices.

    ``neigh[v]`` lists the (type-offset) tokens vertex ``v``'s Neighbor
    Aggregation reads.  LDG-style greedy: assign ``v`` to the partition whose
    already-assigned vertices share the most tokens with it, damped by a load
    penalty and hard-capped at ``ceil(n / k)`` — co-locating vertices that
    read the same source rows is what shrinks both the cut and the halo.
    Deterministic (ties break toward the lighter, lower-indexed partition).
    """
    n = len(neigh)
    cap = -(-n // k) if n else 1
    owner = np.zeros(n, np.int32)
    loads = np.zeros(k, np.float64)
    # token -> per-partition count of assigned vertices that read it
    counts = np.zeros((max(n_tokens, 1), k), np.float64)
    for v in range(n):
        toks = neigh[v]
        if toks.size:
            score = counts[toks].sum(axis=0)
        else:
            score = np.zeros(k)
        score = score * (1.0 - loads / cap) - 1e-9 * loads
        score[loads >= cap] = -np.inf
        j = int(np.argmax(score))
        owner[v] = j
        loads[j] += 1.0
        if toks.size:
            counts[toks, j] += 1.0
    return owner


def reference_assign(votes: np.ndarray, k: int) -> np.ndarray:
    """Assign source-type vertices by reference majority.

    ``votes[v, j]`` counts how many partition-``j`` destination rows read
    vertex ``v``; each vertex goes to its strongest reader (capacity-bounded
    at ``ceil(n / k)``, strongest-preference vertices placed first), so a row
    read mostly by one partition is *owned* there and never crosses the wire.
    Unreferenced vertices fill the lightest partitions.
    """
    n = votes.shape[0]
    cap = -(-n // k) if n else 1
    owner = np.zeros(n, np.int32)
    loads = np.zeros(k, np.int64)
    order = np.argsort(-votes.max(axis=1), kind="stable")
    for v in order:
        pref = np.argsort(-(votes[v] - 1e-9 * loads), kind="stable")
        for j in pref:
            if loads[j] < cap:
                owner[v] = j
                loads[j] += 1
                break
    return owner


# ---------------------------------------------------------------------------
# per-type partition + halo tables
# ---------------------------------------------------------------------------


@dataclass
class TypePartition:
    """One node type's vertex assignment in own-order coordinates."""

    owner: np.ndarray  # [N] int32 partition id per global vertex
    local: np.ndarray  # [N] int32 position within the owner's table
    own: np.ndarray  # [K, n_max] int32 global ids (0-padded)
    own_mask: np.ndarray  # [K, n_max] float32 {0,1}

    @property
    def n_max(self) -> int:
        return self.own.shape[1]

    @property
    def flat(self) -> np.ndarray:
        """[N] flat own-order index (``owner * n_max + local``)."""
        return (self.owner.astype(np.int64) * self.n_max
                + self.local.astype(np.int64))


def build_type_partition(owner: np.ndarray, k: int,
                         pad_to: int = 0) -> TypePartition:
    """``pad_to`` raises ``n_max`` to an assignment-independent capacity
    (``ceil(n / k)``, the partitioner's hard load cap) so static-shape plans
    get owned tables whose shape is a pure function of ``(n, k)``.  Pad rows
    carry ``own_mask = 0`` and zero features, so they are numerically inert —
    the inverse permutation never reads them."""
    n = len(owner)
    sizes = np.bincount(owner, minlength=k) if n else np.zeros(k, np.int64)
    n_max = max(int(sizes.max()) if n else 0, 1, int(pad_to))
    own = np.zeros((k, n_max), np.int32)
    own_mask = np.zeros((k, n_max), np.float32)
    local = np.zeros(n, np.int32)
    for j in range(k):
        rows = np.flatnonzero(owner == j)
        own[j, : len(rows)] = rows
        own_mask[j, : len(rows)] = 1.0
        local[rows] = np.arange(len(rows), dtype=np.int32)
    return TypePartition(owner.astype(np.int32), local, own, own_mask)


def build_halo(tp: TypePartition, referenced: Sequence[np.ndarray],
               k: int, pad_to: int = 0
               ) -> Tuple[np.ndarray, np.ndarray, List[np.ndarray]]:
    """Halo index maps for one type: per partition, the non-owned vertices it
    reads, padded to ``[K, H_max]`` *flat own-order* indices + mask.  Also
    returns the raw per-partition halo id lists (relabeling needs them).
    ``pad_to`` raises ``H_max`` to an assignment-independent capacity (the
    type's vertex count — no partition can reference more non-owned rows)
    for static-shape plans; pad entries carry ``halo_mask = 0`` and point at
    flat index 0, and no relabeled neighbor table ever addresses them."""
    halos: List[np.ndarray] = []
    for j in range(k):
        refs = np.unique(referenced[j]).astype(np.int64)
        halos.append(refs[tp.owner[refs] != j])
    h_max = max(max((len(h) for h in halos), default=0), int(pad_to))
    halo_src = np.zeros((k, h_max), np.int32)
    halo_mask = np.zeros((k, h_max), np.float32)
    for j, hj in enumerate(halos):
        if len(hj):
            halo_src[j, : len(hj)] = tp.flat[hj]
            halo_mask[j, : len(hj)] = 1.0
    return halo_src, halo_mask, halos


def local_lut(tp: TypePartition, halos: Sequence[np.ndarray],
              k: int) -> np.ndarray:
    """``lut[j, g]`` = partition-``j`` local coordinate of global vertex ``g``
    (owned rows first, halo rows appended after ``n_max``); ``-1`` where the
    vertex is neither owned nor in the halo (never referenced by ``j``)."""
    n = len(tp.owner)
    lut = np.full((k, max(n, 1)), -1, np.int64)
    for j in range(k):
        rows = np.flatnonzero(tp.owner == j)
        lut[j, rows] = tp.local[rows]
        if len(halos[j]):
            lut[j, halos[j]] = tp.n_max + np.arange(len(halos[j]))
    return lut


# ---------------------------------------------------------------------------
# the halo feature exchange (device side)
# ---------------------------------------------------------------------------


def gather_halo(h_own: jax.Array, halo_src: jax.Array,
                mode: str = "auto") -> jax.Array:
    """Fetch halo feature rows from the stacked owned tables.

    ``h_own``: ``[K, n_max, ...]`` per-partition owned features;
    ``halo_src``: ``[K, H_max]`` flat own-order indices (``owner * n_max +
    local``).  Returns ``[K, H_max, ...]``.

    With an active mesh whose BATCH axes divide ``K`` (and ``mode="auto"``),
    this is an explicit ``shard_map`` over the partition dim: each shard
    ``all_gather``s the owned tables once and gathers its halo rows locally —
    the single communication step of the partitioned flow.  Otherwise
    (``mode="xla"``, off-mesh, or a non-dividing mesh) it is a flat gather
    whose cross-shard traffic the partitioner leaves to GSPMD.
    """
    k, n = h_own.shape[:2]
    tail = h_own.shape[2:]
    mesh = current_mesh()
    if mode == "auto" and mesh is not None:
        names = [a for a in BATCH if a in mesh.axis_names]
        size = math.prod(mesh.shape[a] for a in names) if names else 0
        if names and size > 1 and k % size == 0 and halo_src.shape[1] > 0:
            ax = tuple(names) if len(names) > 1 else names[0]

            def body(h, idx):
                h_all = jax.lax.all_gather(h, ax, axis=0, tiled=True)
                return h_all.reshape((k * n,) + tail)[idx]

            return shard_map(
                body, mesh=mesh,
                in_specs=(P(ax, *([None] * (len(tail) + 1))), P(ax, None)),
                out_specs=P(ax, *([None] * (len(tail) + 1))),
                check_rep=False,
            )(h_own, halo_src)
    flat = h_own.reshape((k * n,) + tail)
    out = flat[halo_src]
    return shard(out, BATCH, *([None] * (len(tail) + 1)))


# ---------------------------------------------------------------------------
# batch partitioning (host side, per NA layout)
# ---------------------------------------------------------------------------


def partition_batch(plan, batch: Dict) -> Dict:
    """Post-process a prepared (unpartitioned) device batch into the
    partitioned layout declared by ``plan.partition``.  Dispatches on the
    NA layout; raises for layouts with no partitioned execution mode
    (csr baselines, degree-bucketed tiles)."""
    spec = plan.partition
    if spec is None:
        return batch
    if plan.na.layout == "stacked":
        return _partition_stacked(plan, batch, spec.k)
    if plan.na.layout == "padded" and plan.na.kind == "mean":
        # a multi-layer rel_sum stack updates EVERY node type per layer, so
        # every relation (not just those into the target) must be
        # partitioned, each on its destination type's owners
        if plan.n_layers > 1:
            return _partition_relational_ml(plan, batch, spec.k)
        return _partition_relational(plan, batch, spec.k)
    if plan.na.layout == "instances":
        return _partition_instances(plan, batch, spec.k)
    raise ValueError(
        f"partitioned execution supports the stacked / padded / instances NA "
        f"layouts, not {plan.na.layout!r} (model {plan.model!r}): baselines "
        "and degree-bucketed tiles have no per-partition relabeling")


def _part_feats(feats: np.ndarray, tp: TypePartition) -> np.ndarray:
    """Distribute raw feature rows to their owners ([K, n_max, F], zero-pad)."""
    return (feats[tp.own] * tp.own_mask[..., None]).astype(feats.dtype)


def _static_pads(plan, counts: Dict[str, int], k: int):
    """Per-type ``(n_pad, h_pad)`` capacities for ``static_shapes`` plans.

    ``n_pad[ty] = ceil(n_ty / k)`` (the assignment cap every partitioner
    obeys) and ``h_pad[ty] = n_ty`` (no partition can reference more
    non-owned rows than the type has), so every partitioned table shape is a
    pure function of the *unpartitioned* batch shapes and ``k``.  Sampled
    serving pads each rung's batch to rung-fixed caps, so with these pads the
    per-step re-partition stops choosing data-dependent halo widths and the
    warmed jit cache covers every step (``compiles_after_warmup == 0``).
    Returns ``({}, {})`` — dynamic minimal shapes — for non-static plans.
    """
    if plan.partition is None or not plan.partition.static_shapes:
        return {}, {}
    return ({ty: max(-(-int(c) // k), 1) for ty, c in counts.items()},
            {ty: int(c) for ty, c in counts.items()})


EdgeLists = Dict[str, List[Tuple[np.ndarray, np.ndarray]]]


def _source_partitions(
    tp_t: TypePartition, edge_lists: EdgeLists, counts: Dict[str, int],
    k: int, tps: Dict[str, TypePartition],
    pads: Tuple[Dict[str, int], Dict[str, int]] = ({}, {}),
) -> Tuple[Dict, Dict, Dict, int, int]:
    """The shared middle of every layout's partitioning: assign each gathered
    source type, build its halo tables and relabeling LUTs, count the cut.

    ``edge_lists``: type -> list of ``(dst_global, src_global)`` mask-valid
    edge arrays (dst indexes the target type).  Types already in ``tps`` (the
    target itself, self-relations) keep their assignment; the rest are
    reference-majority assigned.  Returns per-type ``(halo_src, halo_mask,
    luts)`` plus the ``(cut_edges, edges_total)`` counters.
    """
    n_pad, h_pad = pads
    halo_src: Dict[str, np.ndarray] = {}
    halo_mask: Dict[str, np.ndarray] = {}
    luts: Dict[str, np.ndarray] = {}
    cut = total = 0
    for s in sorted(edge_lists):
        pairs = edge_lists[s]
        if s not in tps:
            votes = np.zeros((counts[s], k), np.float64)
            for dst, src in pairs:
                np.add.at(votes, (src, tp_t.owner[dst]), 1.0)
            tps[s] = build_type_partition(reference_assign(votes, k), k,
                                          pad_to=n_pad.get(s, 0))
        referenced = []
        for j in range(k):
            ids = [src[tp_t.owner[dst] == j] for dst, src in pairs]
            referenced.append(np.unique(np.concatenate(ids)) if ids
                              else np.zeros(0, np.int64))
        hs, hm, halos = build_halo(tps[s], referenced, k,
                                   pad_to=h_pad.get(s, 0))
        halo_src[s], halo_mask[s] = hs, hm
        luts[s] = local_lut(tps[s], halos, k)
        for dst, src in pairs:
            cut += int((tps[s].owner[src] != tp_t.owner[dst]).sum())
            total += len(dst)
    return halo_src, halo_mask, luts, cut, total


def _part_tables(tps: Dict[str, TypePartition], halo_src: Dict,
                 halo_mask: Dict, feats: Dict, tp_t: TypePartition, k: int,
                 cut: int, total: int) -> Dict:
    """The layout-independent slice of the ``part`` dict (per-type owned
    feature shards + ownership/halo maps + the output inverse permutation)."""
    return {
        "feats": {s: jnp.asarray(_part_feats(np.asarray(feats[s]), tps[s]))
                  for s in sorted(tps)},
        "own": {s: jnp.asarray(tps[s].own) for s in sorted(tps)},
        "own_mask": {s: jnp.asarray(tps[s].own_mask) for s in sorted(tps)},
        "halo_src": {s: jnp.asarray(halo_src[s]) for s in sorted(halo_src)},
        "halo_mask": {s: jnp.asarray(halo_mask[s])
                      for s in sorted(halo_mask)},
        "inv": jnp.asarray(tp_t.flat.astype(np.int32)),
        "meta": {"k": k, "cut_edges": cut, "edges_total": total},
    }


def _partition_stacked(plan, batch: Dict, k: int) -> Dict:
    """HAN's ``[P, N, Kd]`` stacked metapath layout: destination = source =
    target type; one halo table; neighbor stack relabeled per partition."""
    nbr = np.asarray(batch["nbr"])
    mask = np.asarray(batch["mask"])
    p_, n, kd = nbr.shape
    t = plan.target
    valid = mask > 0
    neigh = [np.unique(nbr[:, v][valid[:, v]]) for v in range(n)]
    pads = _static_pads(plan, {t: n}, k)
    tp = build_type_partition(edge_cut_assign(neigh, n, k), k,
                              pad_to=pads[0].get(t, 0))
    tps = {t: tp}
    pi, ni, ki = np.nonzero(valid)
    halo_src, halo_mask, luts, cut, total = _source_partitions(
        tp, {t: [(ni, nbr[pi, ni, ki])]}, {t: n}, k, tps, pads=pads)
    nbr_p = np.zeros((k, p_, tp.n_max, kd), np.int32)
    mask_p = np.zeros((k, p_, tp.n_max, kd), np.float32)
    for j in range(k):
        rows = np.flatnonzero(tp.owner == j)
        nbr_p[j, :, : len(rows)] = np.maximum(luts[t][j, nbr[:, rows]], 0)
        mask_p[j, :, : len(rows)] = mask[:, rows]
    part = _part_tables(tps, halo_src, halo_mask, batch["feats"], tp, k,
                        cut, total)
    part["nbr"] = jnp.asarray(nbr_p)
    part["mask"] = jnp.asarray(mask_p)
    return {
        "feat_dims": batch["feat_dims"],
        "n_nodes": batch["n_nodes"],
        "part": part,
    }


def _target_edge_cut(rels_t: Dict, counts: Dict[str, int], n: int,
                     k: int, pad_to: int = 0) -> TypePartition:
    """Edge-cut assignment of the target type from its incoming padded
    relations: each destination row's token set is the (type-offset) union
    of its source reads, so rows sharing sources co-locate."""
    src_types = sorted({key[0] for key in rels_t})
    offs, off = {}, 0
    for s in src_types:
        offs[s] = off
        off += counts[s]
    neigh = []
    for v in range(n):
        toks = [r_nbr[v][r_mask[v] > 0] + offs[key[0]]
                for key, (r_nbr, r_mask) in sorted(rels_t.items())]
        neigh.append(np.unique(np.concatenate(toks)) if toks
                     else np.zeros(0, np.int64))
    return build_type_partition(edge_cut_assign(neigh, max(off, 1), k), k,
                                pad_to=pad_to)


def _partition_relational(plan, batch: Dict, k: int) -> Dict:
    """RGCN's per-relation ``[N_d, Kd]`` padded layout: only relations into
    the target type feed the head; the target is edge-cut-assigned, every
    source type reference-assigned, one halo table per source type."""
    t = plan.target
    rels = {key: (np.asarray(v[0]), np.asarray(v[1]))
            for key, v in batch["rels"].items() if key[2] == t}
    counts = {ty: int(c) for ty, c in batch["counts"].items()}
    pads = _static_pads(plan, counts, k)
    tp_t = _target_edge_cut(rels, counts, counts[t], k,
                            pad_to=pads[0].get(t, 0))
    tps: Dict[str, TypePartition] = {t: tp_t}  # self-relations reuse it
    edge_lists: EdgeLists = {t: []}  # target always gets a (maybe empty) halo
    for key, (r_nbr, r_mask) in sorted(rels.items()):
        di, ci = np.nonzero(r_mask > 0)
        edge_lists.setdefault(key[0], []).append((di, r_nbr[di, ci]))
    halo_src, halo_mask, luts, cut, total = _source_partitions(
        tp_t, edge_lists, counts, k, tps, pads=pads)
    rels_p: Dict = {}
    for key, (r_nbr, r_mask) in rels.items():
        s = key[0]
        kd = r_nbr.shape[1]
        nbr_p = np.zeros((k, tp_t.n_max, kd), np.int32)
        mask_p = np.zeros((k, tp_t.n_max, kd), np.float32)
        for j in range(k):
            rows = np.flatnonzero(tp_t.owner == j)
            nbr_p[j, : len(rows)] = np.maximum(luts[s][j, r_nbr[rows]], 0)
            mask_p[j, : len(rows)] = r_mask[rows]
        rels_p[key] = (jnp.asarray(nbr_p), jnp.asarray(mask_p))
    part = _part_tables(tps, halo_src, halo_mask, batch["feats"], tp_t, k,
                        cut, total)
    part["rels"] = rels_p
    return {
        "feat_dims": batch["feat_dims"],
        "counts": batch["counts"],
        # keys only (init splits w_rel per sorted key); tables live in `part`
        "rels": {key: () for key in batch["rels"]},
        "part": part,
    }


def _partition_relational_ml(plan, batch: Dict, k: int) -> Dict:
    """RGCN's padded layout for an L-layer stack: hidden rel_sum layers
    update *every* node type, so every relation partitions — each on its
    **destination type's** owners — and every type gets halo tables covering
    the union of reads from all of its readers' owned destination rows.
    The halo maps stay graph-invariant across layers; only the exchanged
    features change, so ``gather_halo`` simply re-runs per layer.

    Assignment: the target type keeps the metapath-aware edge-cut (same
    construction as the single-layer path); the remaining types are
    reference-majority assigned from relations whose destination type is
    already assigned (breadth-first from the target, so votes always come
    from settled owners); types nobody reads fill round-robin.
    """
    t = plan.target
    rels = {key: (np.asarray(v[0]), np.asarray(v[1]))
            for key, v in batch["rels"].items()}
    counts = {ty: int(c) for ty, c in batch["counts"].items()}
    n_pad, h_pad = _static_pads(plan, counts, k)
    # --- target assignment: edge-cut over the relations INTO the target
    # (same construction as the single-layer path) ---
    rels_t = {key: v for key, v in rels.items() if key[2] == t}
    tps: Dict[str, TypePartition] = {
        t: _target_edge_cut(rels_t, counts, counts[t], k,
                            pad_to=n_pad.get(t, 0))}
    # --- remaining types: reference majority from settled destinations ---
    remaining = [ty for ty in sorted(counts) if ty not in tps]
    while remaining:
        progress = False
        for ty in list(remaining):
            votes = np.zeros((counts[ty], k), np.float64)
            seen = False
            for key, (r_nbr, r_mask) in sorted(rels.items()):
                s, _, d = key
                if s != ty or d not in tps:
                    continue
                di, ci = np.nonzero(r_mask > 0)
                np.add.at(votes, (r_nbr[di, ci], tps[d].owner[di]), 1.0)
                seen = True
            if seen:
                tps[ty] = build_type_partition(reference_assign(votes, k), k,
                                               pad_to=n_pad.get(ty, 0))
                remaining.remove(ty)
                progress = True
        if not progress:  # types unreachable from the target: round-robin
            for ty in remaining:
                owner = (np.arange(counts[ty]) % k).astype(np.int32)
                tps[ty] = build_type_partition(owner, k,
                                               pad_to=n_pad.get(ty, 0))
            remaining = []
    # --- halos per source type from ALL relations (per-dst-type owners) ---
    halo_src: Dict[str, np.ndarray] = {}
    halo_mask: Dict[str, np.ndarray] = {}
    luts: Dict[str, np.ndarray] = {}
    cut = total = 0
    for s in sorted(counts):
        pairs = []  # (dst_owner per edge, src global ids)
        for key, (r_nbr, r_mask) in sorted(rels.items()):
            if key[0] != s:
                continue
            di, ci = np.nonzero(r_mask > 0)
            pairs.append((tps[key[2]].owner[di], r_nbr[di, ci]))
        referenced = []
        for j in range(k):
            ids = [src[downer == j] for downer, src in pairs]
            referenced.append(np.unique(np.concatenate(ids)) if ids
                              else np.zeros(0, np.int64))
        hs, hm, halos = build_halo(tps[s], referenced, k,
                                   pad_to=h_pad.get(s, 0))
        halo_src[s], halo_mask[s] = hs, hm
        luts[s] = local_lut(tps[s], halos, k)
        for downer, src in pairs:
            cut += int((tps[s].owner[src] != downer).sum())
            total += len(src)
    # --- relabel every relation on its destination type's owners ---
    rels_p: Dict = {}
    for key, (r_nbr, r_mask) in rels.items():
        s, _, d = key
        tpd = tps[d]
        kd = r_nbr.shape[1]
        nbr_p = np.zeros((k, tpd.n_max, kd), np.int32)
        mask_p = np.zeros((k, tpd.n_max, kd), np.float32)
        for j in range(k):
            rows = np.flatnonzero(tpd.owner == j)
            nbr_p[j, : len(rows)] = np.maximum(luts[s][j, r_nbr[rows]], 0)
            mask_p[j, : len(rows)] = r_mask[rows]
        rels_p[key] = (jnp.asarray(nbr_p), jnp.asarray(mask_p))
    part = _part_tables(tps, halo_src, halo_mask, batch["feats"], tps[t], k,
                        cut, total)
    part["rels"] = rels_p
    return {
        "feat_dims": batch["feat_dims"],
        "counts": batch["counts"],
        # keys only (init splits w_rel per sorted key); tables live in `part`
        "rels": {key: () for key in batch["rels"]},
        "part": part,
    }


def _partition_instances(plan, batch: Dict, k: int) -> Dict:
    """MAGNN's sampled ``[N, I, L]`` instance tables: every path position is a
    typed gather, so each referenced type gets its own halo table and the
    instance node ids relabel per position through that type's LUT."""
    t = plan.target
    insts = [(np.asarray(nodes), np.asarray(m))
             for nodes, m in batch["instances"]]
    counts = {ty: int(f.shape[0]) for ty, f in batch["feats"].items()}
    n = counts[t]
    types_used = sorted({ty for path in plan.metapaths for ty in path})
    offs, off = {}, 0
    for ty in types_used:
        offs[ty] = off
        off += counts[ty]
    neigh = []
    for v in range(n):
        toks = []
        for (nodes, m), path in zip(insts, plan.metapaths):
            rows = nodes[v][m[v] > 0]  # [i_valid, L]
            for j, ty in enumerate(path):
                if j == 0:
                    continue  # position 0 is the target row itself
                toks.append(rows[:, j].astype(np.int64) + offs[ty])
        neigh.append(np.unique(np.concatenate(toks)) if toks
                     else np.zeros(0, np.int64))
    pads = _static_pads(plan, counts, k)
    tp_t = build_type_partition(edge_cut_assign(neigh, max(off, 1), k), k,
                                pad_to=pads[0].get(t, 0))
    tps: Dict[str, TypePartition] = {t: tp_t}
    edge_lists: EdgeLists = {t: []}  # target always gets a (maybe empty) halo
    for (nodes, m), path in zip(insts, plan.metapaths):
        di, ii = np.nonzero(m > 0)
        for j, ty in enumerate(path):
            if j == 0:
                continue  # position 0 is the (owned) target row itself
            edge_lists.setdefault(ty, []).append((di, nodes[di, ii, j]))
    halo_src, halo_mask, luts, cut, total = _source_partitions(
        tp_t, edge_lists, counts, k, tps, pads=pads)
    insts_p = []
    for (nodes, m), path in zip(insts, plan.metapaths):
        _, i, l = nodes.shape
        nodes_p = np.zeros((k, tp_t.n_max, i, l), np.int32)
        mask_p = np.zeros((k, tp_t.n_max, i), np.float32)
        for part_j in range(k):
            rows = np.flatnonzero(tp_t.owner == part_j)
            relab = np.stack(
                [np.maximum(luts[path[j]][part_j, nodes[rows][:, :, j]], 0)
                 for j in range(l)], axis=-1)
            nodes_p[part_j, : len(rows)] = relab
            mask_p[part_j, : len(rows)] = m[rows]
        insts_p.append((jnp.asarray(nodes_p), jnp.asarray(mask_p)))
    part = _part_tables(tps, halo_src, halo_mask, batch["feats"], tp_t, k,
                        cut, total)
    part["instances"] = insts_p
    return {
        "feat_dims": batch["feat_dims"],
        "n_nodes": batch["n_nodes"],
        "part": part,
    }


# ---------------------------------------------------------------------------
# partition failover (serving resilience)
# ---------------------------------------------------------------------------


def surviving_partition_spec(spec, failed: Sequence[int]):
    """Surviving-topology rebuild on the graph-partition axis.

    The ``train/elastic.surviving_mesh`` idea applied to partitioned
    serving: when a partition (its host/device arm) is lost mid-serve, the
    next sampled batch is simply re-partitioned over the survivors — the
    partitioner re-assigns every vertex (including the lost partition's)
    across ``k - len(failed)`` partitions from scratch, because assignment,
    halo maps and relabeling are all pure functions of (batch, k).  The
    partitioned head's inverse permutation restores global row order
    whatever the assignment, so post-failover logits stay bit-exact vs a
    never-failed run (the K-parity invariant from the partition tests).
    """
    from dataclasses import replace

    lost = {int(f) for f in failed}
    bad = [f for f in lost if not 0 <= f < spec.k]
    if bad:
        raise ValueError(f"failed partition ids {sorted(bad)} out of range "
                         f"for k={spec.k}")
    keep = spec.k - len(lost)
    if keep < 1:
        raise RuntimeError("no surviving partitions")
    return replace(spec, k=keep)
