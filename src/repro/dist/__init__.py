"""Distributed runtime: mesh axis conventions, sharding constraints and
parameter partitioning.

Two modules:

* :mod:`repro.dist.sharding`       — axis-name constants (``BATCH``/``MODEL``),
  the ``shard`` constraint helper, the ``use_mesh`` context manager and the
  ``resolve_spec`` resolve-or-replicate spec resolver.
* :mod:`repro.dist.param_sharding` — ``param_specs``: walk a parameter pytree
  and assign a ``NamedSharding`` per leaf (TP over 'model', optional FSDP
  over 'data', EP for expert weights, replication for small vectors).
* :mod:`repro.dist.partition`      — graph-partitioned multi-host execution:
  the metapath-aware edge-cut partitioner (per-type vertex assignment,
  halo/ghost-vertex index maps, per-partition relabeling) and the
  ``gather_halo`` feature exchange (shard_map over the BATCH axes).
  Imported lazily by the executor — it pulls in jax.experimental.
"""
from repro.dist.sharding import (  # noqa: F401
    BATCH,
    DATA,
    MODEL,
    POD,
    current_mesh,
    resolve_spec,
    shard,
    use_mesh,
)
from repro.dist.param_sharding import param_specs  # noqa: F401
