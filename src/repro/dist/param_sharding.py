"""Parameter partitioning: pytree -> matching NamedSharding pytree.

``param_specs`` walks a parameter pytree by *key path* and assigns each leaf
a :class:`jax.sharding.NamedSharding` built from the repo's logical axes
(see :mod:`repro.dist.sharding` for the resolve-or-replicate contract):

* **DM-Type projection matrices** (the dense Feature-Projection analogue:
  ``wq/wk/wv``, MLP ``w_gate/w_up``, Mamba2 ``w_z/w_x/w_dt``, ``lm_head``)
  are column-sharded — output dim over ``'model'`` (Megatron layout).
* **Row-sharded contractions** (``wo``, ``w_down``, Mamba2 ``out_proj``)
  shard the input dim over ``'model'`` so each block ends in exactly one
  all-reduce.
* **Expert weights** (a leaf named ``w_gate/w_up/w_down`` whose immediate
  parent is ``'moe'``) shard the *expert* dim over ``'model'`` (expert
  parallelism matching the ``shard(xe, None, MODEL, ...)`` dispatch buffer).
* **Embeddings** shard vocab over ``'model'`` (logits come out
  vocab-sharded) and, under FSDP, d_model over ``'data'``.
* **Small EW-Type vectors** (norm scales, biases, attention vectors
  ``a_dst/a_src``, SSM ``A_log/D/dt_bias``, conv taps, routers) are
  replicated — their all-gather would cost more than their bytes.

FSDP (ZeRO-3) additionally shards one non-model dim of every large matrix
over ``'data'`` (``fsdp=`` for dense weights, ``fsdp_experts=`` for expert
weights).  Every rule goes through ``resolve_spec``, so a dim that does not
divide the axis simply stays replicated — the same table serves reduced CPU
configs and the production mesh.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.tree_util import DictKey, tree_map_with_path

from repro.dist.sharding import DATA, MODEL, resolve_spec

# Column-sharded: output (last) dim over 'model'.
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_x", "w_dt", "lm_head"}
# Row-sharded: input (second-to-last) dim over 'model'.
_ROW = {"wo", "w_down", "out_proj"}
# Expert-parallel leaves when the enclosing block is a 'moe' dict.
_EXPERT = {"w_gate", "w_up", "w_down"}


def _dict_keys(path: Tuple[Any, ...]) -> Tuple[str, ...]:
    return tuple(k.key for k in path if isinstance(k, DictKey))


def _leaf_spec(path, leaf, fsdp: bool, fsdp_experts: bool):
    """Logical per-dim spec for one leaf (before mesh resolution).

    Works on trailing dims so the same rule covers a bare block ([d, f]),
    a scan-stacked run ([L, d, f]) and stacked expert weights ([L, E, d, f]).
    """
    names = _dict_keys(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    nd = leaf.ndim
    spec = [None] * nd

    if name == "embed" and nd == 2:
        spec[0] = MODEL
        if fsdp:
            spec[1] = DATA
    elif parent == "moe" and name in _EXPERT and nd >= 3:
        spec[-3] = MODEL  # expert dim
        if fsdp_experts:
            spec[-2] = DATA
    elif name in _COL and nd >= 2:
        spec[-1] = MODEL
        if fsdp:
            spec[-2] = DATA
    elif name in _ROW and nd >= 2:
        spec[-2] = MODEL
        if fsdp:
            spec[-1] = DATA
    # everything else (norms, biases, attention/SSM vectors, routers, conv
    # taps, HGNN attention vectors) stays fully replicated
    return tuple(spec)


def param_specs(params: Any, mesh: Mesh, fsdp: bool = True,
                fsdp_experts: bool = True) -> Any:
    """NamedSharding pytree matching ``params`` (leaves may be concrete
    arrays or ``ShapeDtypeStruct``s from ``jax.eval_shape``)."""

    def one(path, leaf):
        spec = _leaf_spec(path, leaf, fsdp, fsdp_experts)
        return NamedSharding(mesh, resolve_spec(leaf.shape, spec, mesh))

    return tree_map_with_path(one, params)
