"""Axis-name conventions and the resolve-or-replicate sharding contract.

Axis-name conventions
---------------------
Every mesh in this repo is built from (a subset of) three named axes:

* ``"pod"``   — pure data parallelism across slices; the only cross-pod
  collective a step is allowed to need is the gradient all-reduce.
* ``"data"``  — data parallelism within a pod; also the FSDP (ZeRO-3) axis
  for parameter/optimizer-state sharding.
* ``"model"`` — tensor/expert parallelism within a pod.

Layer code never names mesh axes directly; it uses the two *logical* axis
constants exported here:

* ``BATCH = ("pod", "data")`` — batch-like dims (tokens, destination nodes in
  the HGNN Neighbor Aggregation stage) shard over every data-parallel axis
  that exists on the current mesh.
* ``MODEL = "model"``         — hidden/head/expert/vocab dims.

The resolve-or-replicate contract
---------------------------------
``resolve_spec(shape, spec, mesh)`` turns a logical per-dim spec into a
concrete :class:`jax.sharding.PartitionSpec` for *this* mesh, degrading
gracefully instead of erroring:

1. Mesh axes named in the spec but absent from ``mesh.axis_names`` are
   dropped (a smoke mesh has no ``"pod"`` axis; ``BATCH`` resolves to just
   ``"data"``).
2. If the dimension size is not divisible by the product of the surviving
   axis sizes, that dim falls back to replication (``None``).  This is what
   lets one spec table serve both the 256-chip production mesh and a 2x4
   host-platform test mesh: a 15-wide dim on a ``model=4`` mesh simply stays
   replicated rather than triggering a GSPMD error.
3. An empty spec (or spec entries beyond ``len(shape)``) mean "replicated".

``shard(x, *spec)`` applies the resolved spec as a
``with_sharding_constraint`` against the mesh installed by ``use_mesh``; with
no active mesh it is a no-op, so single-device code paths (unit tests, the
plain ``jax.jit`` in ``repro.launch.train``) run the exact same layer code.
"""
from __future__ import annotations

import contextlib
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis names (see module docstring).
POD = "pod"
DATA = "data"
MODEL = "model"
BATCH = (POD, DATA)

# Stack, not a single slot: build_step nests (dry-run builds a step while a
# surrounding launcher mesh is active).  Tracing is single-threaded.
_MESH_STACK: List[Mesh] = []


def current_mesh() -> Optional[Mesh]:
    """The innermost mesh installed by :func:`use_mesh` (None outside)."""
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the target of :func:`shard` constraints.

    Used around step-function *tracing* (see ``repro.launch.steps``): the
    constraints captured in the jaxpr then name this mesh's axes.
    """
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def _flatten_axes(entry: Any) -> Tuple[str, ...]:
    """Flatten a spec entry (name | nested tuples/lists of names) to names."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    out: List[str] = []
    for e in entry:
        out.extend(_flatten_axes(e))
    return tuple(out)


def resolve_spec(shape: Sequence[int], spec: Sequence[Any], mesh: Mesh) -> P:
    """Resolve a logical per-dim spec against ``mesh`` (see module docstring).

    ``spec`` entries may be ``None``, a mesh-axis name, or an (arbitrarily
    nested) tuple of axis names.  Returns a ``PartitionSpec`` with exactly
    ``min(len(spec), len(shape))`` entries; single-axis tuples collapse to
    the bare name so results compare equal to hand-written specs.
    """
    # mesh.shape is {axis_name: size}; duck-typed so tests can resolve
    # against an abstract mesh description without real devices
    axis_sizes = dict(mesh.shape)
    out: List[Any] = []
    for dim, entry in zip(shape, spec):
        names = [n for n in _flatten_axes(entry) if n in axis_sizes]
        if not names:
            out.append(None)
            continue
        total = 1
        for n in names:
            total *= axis_sizes[n]
        if int(dim) % total != 0:  # divisibility guard -> replicate this dim
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
    return P(*out)


def shard(x: jax.Array, *spec: Any) -> jax.Array:
    """Constrain ``x`` to the resolved spec on the active mesh (no-op
    without one).  ``spec`` is one logical entry per dim of ``x``."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(x.shape, spec, mesh)))
