"""Checkpoint / restart.

Layout (per checkpoint directory):
    step_<N>/
      manifest.json       step, n_leaves, shapes/dtypes, config name, digest
      shard_<host>.npz    flattened leaves owned by this host

Properties needed at 1000+ nodes, all implemented here:
  * atomic publish  — write to ``step_<N>.tmp`` then ``os.rename`` (readers
    never observe partial checkpoints);
  * async save      — a background thread drains a 1-deep queue so training
    never blocks on disk;
  * integrity       — per-shard content digest verified on restore;
  * elastic restore — leaves are loaded host-side and ``jax.device_put`` with
    the TARGET mesh's shardings, so a checkpoint taken on 512 chips restarts
    on 256 (or any other mesh) unchanged.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaves(tree):
    return jax.tree.leaves(tree)


def save(state: Any, ckpt_dir: str, step: int, host_id: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = [np.asarray(x) for x in _leaves(state)]
    arrs = {f"leaf_{i:05d}": a for i, a in enumerate(leaves)}
    shard_path = os.path.join(tmp, f"shard_{host_id:05d}.npz")
    np.savez(shard_path, **arrs)
    digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "shapes": [list(a.shape) for a in leaves],
        "dtypes": [str(a.dtype) for a in leaves],
        "digest": {str(host_id): digest},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None, host_id: int = 0) -> Any:
    """Restore into the structure of ``like`` (a state pytree or eval_shape
    thereof). ``shardings``: optional matching NamedSharding tree — leaves are
    device_put with it (elastic restore onto any mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    shard_path = os.path.join(d, f"shard_{host_id:05d}.npz")
    digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
    want = manifest["digest"].get(str(host_id))
    if want is not None and want != digest:
        raise IOError(f"checkpoint shard corrupt: {shard_path}")
    data = np.load(shard_path)
    leaves = [data[f"leaf_{i:05d}"] for i in range(manifest["n_leaves"])]
    treedef = jax.tree.structure(like)
    flat_like = jax.tree.leaves(like)
    assert len(flat_like) == len(leaves), (len(flat_like), len(leaves))
    for a, l in zip(leaves, flat_like):
        assert tuple(a.shape) == tuple(l.shape), (a.shape, l.shape)
    if shardings is not None:
        flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
        leaves = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                  for a, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.numpy.asarray(a) for a in leaves]
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background writer: ``submit`` returns immediately; a single worker
    drains a 1-deep queue (newer snapshots overwrite queued older ones)."""

    def __init__(self, ckpt_dir: str, host_id: int = 0):
        self.ckpt_dir = ckpt_dir
        self.host_id = host_id
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            state_np, step = item
            try:
                save(state_np, self.ckpt_dir, step, self.host_id)
            except BaseException as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, state: Any, step: int):
        if self._err:
            raise self._err
        state_np = jax.tree.map(lambda x: np.asarray(x), state)  # snapshot
        try:
            self._q.put_nowait((state_np, step))
        except queue.Full:  # drop the older queued snapshot
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._q.put_nowait((state_np, step))

    def close(self):
        self._q.put(None)
        self._worker.join()
        if self._err:
            raise self._err
