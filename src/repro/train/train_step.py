"""Train step: loss -> grad -> clip -> optimizer, with optional microbatch
gradient accumulation (scanned, so XLA overlaps microbatch i's gradient
all-reduce with microbatch i+1's compute — the standard comm/compute overlap).

Gradients are computed in the model dtype (bf16) so cross-pod all-reduces move
half the bytes of fp32 (gradient compression); the optimizer update is fp32.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.param_sharding import param_specs
from repro.train.optimizer import Optimizer, build_optimizer, clip_by_global_norm


class TrainState(NamedTuple):
    step: jax.Array  # [] int32
    params: Any
    opt: Any


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        from repro.nn.encdec import encdec_loss

        return functools.partial(encdec_loss, cfg=cfg)
    from repro.nn.transformer import lm_loss

    return functools.partial(lm_loss, cfg=cfg)


def init_train_state(rng, cfg: ModelConfig, optimizer: Optimizer) -> TrainState:
    if cfg.family == "encdec":
        from repro.nn.encdec import init_encdec_params

        params = init_encdec_params(rng, cfg)
    else:
        from repro.nn.transformer import init_lm_params

        params = init_lm_params(rng, cfg)
    return TrainState(jnp.zeros((), jnp.int32), params, optimizer.init(params))


def state_shardings(state_shapes: TrainState, optimizer: Optimizer, mesh,
                    fsdp: bool = True, fsdp_experts: bool = True):
    pspecs = param_specs(state_shapes.params, mesh, fsdp=fsdp,
                         fsdp_experts=fsdp_experts)
    return TrainState(
        step=NamedSharding(mesh, P()),
        params=pspecs,
        opt=optimizer.state_specs(pspecs, mesh),
    )


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    n_microbatches: int = 1, clip_norm: float = 1.0):
    loss_fn = loss_fn_for(cfg)

    def single(params, batch):
        return loss_fn(params, batch=batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if n_microbatches <= 1:
            loss, grads = jax.value_and_grad(single)(state.params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // n_microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc, loss_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, grads = jax.value_and_grad(single)(state.params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(n_microbatches))
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt = optimizer.update(grads, state.opt, state.params, state.step)
        new_state = TrainState(state.step + 1, params, opt)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
