"""Elastic scaling, failure handling and straggler policy.

On a real multi-slice deployment the controller observes slice health and
restarts the job with the surviving topology; everything below is the
framework-side machinery that makes that restart cheap and deterministic:

  * ``surviving_mesh``  — rebuild the production mesh from surviving pods
    (drop the failed 'pod' slices; fall back to single-pod when one remains).
  * ``reshard_state``   — device_put a restored checkpoint onto ANY mesh
    (composes with checkpoint.restore: 512-chip state -> 256-chip mesh).
  * ``data_shard``      — deterministic (step, host) -> sample-range mapping:
    no central dispatcher = no straggler head-of-line blocking on input; a
    restarted host recomputes exactly the batch slice it owes.
  * straggler policy    — the on-device step is synchronous SPMD, so per-chip
    stragglers surface as step-time jitter; mitigation implemented here is
    bounded checkpoint cadence + deterministic resharding (hot-spare slices
    swap in with no data-pipeline coordination).
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.dist.param_sharding import param_specs


def surviving_mesh(mesh: Mesh, failed_pods: Sequence[int]) -> Mesh:
    """Drop failed 'pod' slices from a (pod, data, model) mesh."""
    if "pod" not in mesh.axis_names:
        raise ValueError("surviving_mesh expects a multi-pod mesh")
    pod_axis = mesh.axis_names.index("pod")
    keep = [i for i in range(mesh.devices.shape[pod_axis]) if i not in set(failed_pods)]
    if not keep:
        raise RuntimeError("no surviving pods")
    devices = np.take(mesh.devices, keep, axis=pod_axis)
    if len(keep) == 1:  # collapse to single-pod topology
        devices = devices.reshape(devices.shape[1:])
        return Mesh(devices, tuple(n for n in mesh.axis_names if n != "pod"))
    return Mesh(devices, mesh.axis_names)


def reshard_state(state: Any, shardings: Any) -> Any:
    """device_put every leaf with the target sharding (cross-mesh restore)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings,
        is_leaf=lambda x: x is None)


def data_shard(step: int, host_id: int, n_hosts: int, global_batch: int,
               dataset_size: int) -> Tuple[int, int]:
    """Deterministic [start, end) sample range for (step, host).

    Pure function of its arguments — any host (or its replacement) can
    recompute its slice after a restart without coordination.
    """
    per_host = global_batch // n_hosts
    start = (step * global_batch + host_id * per_host) % dataset_size
    return start, start + per_host


class StepTimer:
    """Bounded-staleness straggler detector: flags steps slower than
    ``threshold`` x the running median (the multi-slice signal used to rotate
    a hot-spare slice in)."""

    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.times: List[float] = []
        self.window = window

    def observe(self, seconds: float) -> bool:
        self.times.append(seconds)
        self.times = self.times[-self.window:]
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times))
        return seconds > self.threshold * med
