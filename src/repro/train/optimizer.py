"""Optimizers from scratch (no optax): AdamW and Adafactor (factored second
moment — what lets arctic-480b's optimizer state fit a 256-chip pod), plus
warmup+cosine schedule and global-norm clipping.

Each optimizer exposes (init, update) and ``state_specs`` so the distributed
runtime can shard optimizer state exactly like (or factored from) the params.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(np.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    state_specs: Callable  # (param_spec_tree, mesh) -> state spec tree


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype="float32") -> Optimizer:
    sd = jnp.dtype(state_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, sd)
        return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            step_v = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            p_new = p.astype(jnp.float32) - lr * (step_v + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), m_new.astype(sd), v_new.astype(sd)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return p_new, AdamWState(mu, nu)

    def state_specs(param_specs, mesh):
        return AdamWState(param_specs, param_specs)

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    vr: Any  # row stats:  param reduced over dim -1
    vc: Any  # col stats:  param reduced over dim -2
    v: Any  # full stats for rank<2 leaves (zeros-sized elsewhere)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor(lr_fn, decay=0.99, eps=1e-30, clip_thresh=1.0,
              weight_decay=0.0) -> Optimizer:
    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros((1,), jnp.float32)

        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        def v(p):
            return jnp.zeros(p.shape, jnp.float32) if not _factored(p) else jnp.zeros((1,), jnp.float32)

        return AdafactorState(jax.tree.map(vr, params), jax.tree.map(vc, params),
                              jax.tree.map(v, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, vr, vc, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr_new = decay * vr + (1 - decay) * g2.mean(axis=-1)
                vc_new = decay * vc + (1 - decay) * g2.mean(axis=-2)
                denom = (vr_new[..., None] / jnp.maximum(
                    vr_new.mean(axis=-1, keepdims=True)[..., None], eps)) * vc_new[..., None, :]
                u = g / jnp.sqrt(jnp.maximum(denom, eps))
                v_new = v
            else:
                v_new = decay * v + (1 - decay) * g2
                u = g / jnp.sqrt(jnp.maximum(v_new, eps))
                vr_new, vc_new = vr, vc
            # update clipping (RMS(u) <= clip_thresh)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            p_new = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), vr_new, vc_new, v_new

        out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(pick(1), pick(2), pick(3))

    def state_specs(param_specs, mesh):
        def drop(ns, which):
            spec = list(ns.spec) + [None] * 8
            # factored stats: spec of the param with one dim removed
            return spec

        def vr_spec(ns):
            s = list(ns.spec)
            if len(s) >= 2:
                return NamedSharding(mesh, P(*s[:-1]))
            return NamedSharding(mesh, P())

        def vc_spec(ns):
            s = list(ns.spec)
            if len(s) >= 2:
                return NamedSharding(mesh, P(*(s[:-2] + s[-1:])))
            return NamedSharding(mesh, P())

        def v_spec(ns):
            return ns if len(ns.spec) < 2 else NamedSharding(mesh, P())

        return AdafactorState(
            jax.tree.map(vr_spec, param_specs),
            jax.tree.map(vc_spec, param_specs),
            jax.tree.map(v_spec, param_specs),
        )

    return Optimizer(init, update, state_specs)


def build_optimizer(cfg, total_steps: int = 10_000) -> Optimizer:
    lr = warmup_cosine(3e-4, min(500, total_steps // 10 + 1), total_steps)
    if cfg.optimizer == "adafactor":
        return adafactor(lr)
    return adamw(lr, state_dtype=cfg.opt_state_dtype)
