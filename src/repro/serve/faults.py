"""Deterministic, seeded fault injection for the HGNN request path.

The serving resilience layer (``repro.serve.resilience`` policies threaded
through ``HGNNServeEngine.serve``) is only trustworthy if its recovery
behavior can be *measured*, and chaos that can't be replayed can't be
gated.  :class:`FaultInjector` therefore holds an explicit schedule of
:class:`Fault` events — sampler exceptions, forward exceptions, injected
step latency, partition loss — and the engine consults it at fixed hook
points:

* ``check("sampler", step, attempt)`` — before the sampler call of every
  retry attempt; raises :class:`InjectedFault` while ``attempt`` is below
  the fault's ``attempts`` count (so ``attempts=1`` is a transient blip the
  first retry absorbs, ``attempts > max_retries`` is a persistent error
  that fails the step's requests).
* ``check("forward", step, attempt)`` — same, before the jitted forward.
* ``latency_s(step)`` — extra seconds added to the step's *observed* wall
  (the SLO/degradation signal) without sleeping, so latency-pressure tests
  and benchmarks stay fast and deterministic.
* ``partition_loss(step)`` — the partition id lost at this step, or None;
  the engine's failover re-assigns the lost partition's vertices over the
  survivors (``repro.dist.partition.surviving_partition_spec``).

``FaultInjector.seeded`` derives a schedule from an integer seed with
``np.random.default_rng`` — same seed, same queue, same schedule, same
counters — which is what lets CI's chaos smoke and
``benchmarks/bench_resilience.py`` assert exact retry/failure/degrade
counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultInjector.check` at a scheduled fault point."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``kind``: ``"sampler"`` / ``"forward"`` (exceptions), ``"latency"``
    (extra observed wall), or ``"partition"`` (partition loss).  For
    exception kinds, ``attempts`` is how many consecutive retry attempts
    at ``step`` raise.
    """
    step: int
    kind: str
    attempts: int = 1
    latency_s: float = 0.0
    partition: int = 0

    def __post_init__(self):
        if self.kind not in ("sampler", "forward", "latency", "partition"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultInjector:
    """A replayable fault schedule plus the counters of what actually fired.

    Deterministic by construction: the schedule is fixed before serving
    starts and the engine's hook points consume it by (kind, step), so two
    runs over the same queue observe byte-identical fault sequences.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self._by_kind: Dict[str, Dict[int, Fault]] = {}
        for f in faults:
            self._by_kind.setdefault(f.kind, {})[f.step] = f
        self.faults = tuple(faults)
        self.counters: Dict[str, int] = {
            "injected_sampler": 0, "injected_forward": 0,
            "injected_latency_steps": 0, "injected_partition_losses": 0,
        }

    # ------------------------------------------------------------------
    # engine hook points
    # ------------------------------------------------------------------
    def check(self, kind: str, step: int, attempt: int) -> None:
        """Raise :class:`InjectedFault` if a ``kind`` fault is scheduled at
        ``step`` and this ``attempt`` is still within its failing window."""
        f = self._by_kind.get(kind, {}).get(step)
        if f is not None and attempt < f.attempts:
            self.counters[f"injected_{kind}"] += 1
            raise InjectedFault(
                f"injected {kind} fault at step {step} (attempt {attempt})")

    def latency_s(self, step: int) -> float:
        """Extra observed wall seconds for this step (0.0 = none)."""
        f = self._by_kind.get("latency", {}).get(step)
        if f is None:
            return 0.0
        self.counters["injected_latency_steps"] += 1
        return float(f.latency_s)

    def partition_loss(self, step: int) -> Optional[int]:
        """Partition id lost at this step, or None."""
        f = self._by_kind.get("partition", {}).get(step)
        if f is None:
            return None
        self.counters["injected_partition_losses"] += 1
        return int(f.partition)

    # ------------------------------------------------------------------
    # seeded schedules
    # ------------------------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, n_steps: int = 16, sampler: int = 0,
               forward: int = 0, persistent_sampler: int = 0,
               latency_steps: int = 0, latency_s: float = 0.05,
               partition_loss_step: Optional[int] = None, partition: int = 0,
               persistent_attempts: int = 64) -> "FaultInjector":
        """Derive a deterministic schedule from ``seed``.

        Transient faults (``sampler`` / ``forward`` counts, ``attempts=1``)
        and ``latency_steps`` latency events land on distinct rng-chosen
        steps in ``[1, n_steps)``; ``persistent_sampler`` faults get
        ``persistent_attempts`` so every retry budget is exhausted.  Steps
        past the actual serve length simply never fire — the schedule stays
        replay-identical either way.
        """
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []

        def draw(n: int, used: set) -> List[int]:
            pool = [s for s in range(1, max(n_steps, 2)) if s not in used]
            take = list(rng.choice(pool, size=min(n, len(pool)),
                                   replace=False)) if pool and n else []
            used.update(int(s) for s in take)
            return [int(s) for s in take]

        used: set = set()
        for s in draw(sampler, used):
            faults.append(Fault(step=s, kind="sampler", attempts=1))
        for s in draw(persistent_sampler, used):
            faults.append(Fault(step=s, kind="sampler",
                                attempts=persistent_attempts))
        for s in draw(forward, used):
            faults.append(Fault(step=s, kind="forward", attempts=1))
        lat_used: set = set()
        for s in draw(latency_steps, lat_used):
            faults.append(Fault(step=s, kind="latency", latency_s=latency_s))
        if partition_loss_step is not None:
            faults.append(Fault(step=int(partition_loss_step),
                                kind="partition", partition=int(partition)))
        return cls(faults)
