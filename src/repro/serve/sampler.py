"""Request-path neighbor sampling (serving-side Subgraph Build).

Serving traffic arrives as requests — "classify these target vertices, now"
— not as a full-graph forward.  :class:`HGNNSampler` extracts, for a set of
target vertices, the k-hop / per-metapath neighborhood of the graph and
relabels it into the *same* device layouts the stage-graph executor already
dispatches on (stacked ``[P, N, K]`` metapath tables for HAN, per-relation
padded tables for RGCN, instance tables for MAGNN, flat edge lists for
GCN), so the executor's arms — baseline / fused / bucketed / epilogue,
L ≥ 1 — run unchanged on the minibatch.

Two properties make this serving-grade rather than a toy:

* **Shape bucketing.**  Every sampled batch is padded to a rung of the
  plan's ``SampleSpec.ladder`` — a small fixed set of ``(t_cap, f_cap)``
  shapes.  The jitted forward compiles once per rung at warmup
  (:meth:`dummy_batch`) and never again: jax caches on pytree structure +
  shapes, and both are rung-determined.  Pad rows carry all-masked neighbor
  lists (the padded aggregators emit exact zeros for them) and the batch's
  ``row_mask`` keeps them out of the semantic-attention score means.

* **Parity by identity.**  The sampler precomputes the full-graph tables
  with *exactly* the model ``prepare()``'s RNG stream (same seed, same
  build-call order).  Whenever a rung's clamped cap covers a whole node
  type, that type is relabeled by the identity and its tables are reused
  verbatim — so a minibatch over *all* targets with fan-out ≥ max degree is
  bit-exact against the full-graph forward (the parity rows in
  ``tests/test_stage_pipeline.py``).

Fan-out caps: per hop, each row keeps the first ``min(fanout, K_table)``
entries of its precomputed padded row (deterministic; the table itself was
degree-capped with the model's RNG).  Overflowing a rung truncates the
*frontier*, farthest hop first — never the targets — and reports the count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import metapath as mp
from repro.core.hgraph import HeteroGraph
from repro.core.plan import StagePlan


@dataclasses.dataclass
class SampledBatch:
    """One relabeled, rung-padded minibatch plus its host-side metadata."""

    batch: Dict  # device batch for StageGraphExecutor.forward
    target_ids: np.ndarray  # [n_targets] global ids, request order
    target_rows: np.ndarray  # [n_targets] local row in the logits table
    rung: Tuple[int, int]
    rung_index: int
    local: Dict[str, np.ndarray]  # type -> [n_real] local->global id map
    meta: Dict  # deterministic traffic record (characterize.sample_traffic)

    @property
    def n_targets(self) -> int:
        return len(self.target_ids)


def _pad_ids(ids: np.ndarray, cap: int) -> np.ndarray:
    out = np.zeros(cap, np.int64)
    out[: len(ids)] = ids
    return out


class _TypeTable:
    """Per-type local vertex table: [targets | frontier (hop order) | pads].

    ``identity`` short-circuits the relabeling when the rung cap covers the
    whole type — local ids == global ids and downstream index tables are
    reused verbatim (the parity path).
    """

    def __init__(self, n_type: int, cap: int, targets: np.ndarray,
                 frontier: np.ndarray):
        self.n_type = n_type
        self.cap = cap
        self.identity = cap == n_type
        if self.identity:
            self.ids = np.arange(n_type, dtype=np.int64)
            self.truncated = 0
        else:
            ids = np.concatenate([targets, frontier])
            self.truncated = max(0, len(ids) - cap)
            if self.truncated:
                # never drop targets: the engine sizes chunks to t_cap and
                # the frontier is hop-ordered, so the tail is the far rim
                assert len(targets) <= cap, (
                    f"targets ({len(targets)}) overflow the rung cap ({cap})")
                ids = ids[:cap]
            self.ids = ids
        self.n_real = len(self.ids)
        self._lut = np.full(n_type, -1, np.int64)
        self._lut[self.ids[::-1]] = np.arange(self.n_real)[::-1]
        # duplicate target ids map to their first occurrence

    def relabel(self, ids: np.ndarray) -> np.ndarray:
        """Global -> local; dropped (truncated) ids come back as -1."""
        return self._lut[ids]

    def rows(self, feats: np.ndarray) -> np.ndarray:
        """The local feature table, zero rows past ``n_real``."""
        if self.identity:
            return feats
        out = np.zeros((self.cap,) + feats.shape[1:], feats.dtype)
        out[: self.n_real] = feats[self.ids]
        return out


class HGNNSampler:
    """Neighbor sampler for one (plan, graph) pair.

    ``plan.sample`` must be set (models declare it when ``cfg.fanout >= 1``).
    The constructor precomputes the full-graph index tables with the model
    ``prepare()``'s exact RNG stream; :meth:`sample` then extracts / relabels
    / rung-pads per request batch — pure numpy until the final device upload.
    """

    def __init__(self, plan: StagePlan, cfg, hg: HeteroGraph):
        if plan.sample is None:
            raise ValueError(
                f"{plan.model}'s plan has no SampleSpec — set cfg.fanout >= 1")
        if plan.na.layout == "csr" and plan.na.kind != "gcn":
            raise ValueError(
                "request-path sampling needs a padded NA layout (the csr "
                "edge lists have no shape-stable minibatch form): set "
                "cfg.fused=True")
        self.plan = plan
        self.cfg = cfg
        self.hg = hg
        self.spec = plan.sample
        self.ladder = tuple(self.spec.ladder)
        self.target = plan.target
        self.n_target_type = hg.node_counts[self.target]
        self.feat_dims = {t: hg.feat_dim(t) for t in hg.features}
        self._build_full_tables()

    # ------------------------------------------------------------------
    # full-graph tables (prepare()'s exact RNG stream)
    # ------------------------------------------------------------------
    def _build_full_tables(self) -> None:
        cfg, plan = self.cfg, self.plan
        rng = np.random.default_rng(cfg.seed)
        kind = plan.na.kind
        if kind == "gat":  # HAN
            self.k_eff = min(self.spec.fanout, cfg.max_degree)
            self.subs = [
                mp.build_padded(self.hg, list(p), cfg.max_degree, rng)
                for p in plan.metapaths
            ]
            if plan.na.layout == "bucketed":
                self.full_buckets = [
                    mp.bucket_padded(s, cfg.degree_buckets) for s in self.subs
                ]
        elif kind == "mean":  # RGCN — replicate prepare()'s loop + RNG order
            self.k_eff = min(self.spec.fanout, cfg.max_degree)
            self.rel_keys = sorted(self.hg.relations.keys())
            self.rel_tables: Dict = {}
            for key in self.rel_keys:
                adj_in = self.hg.relations[key].T.tocsr()
                nbr = np.zeros((adj_in.shape[0], cfg.max_degree), np.int32)
                mask = np.zeros((adj_in.shape[0], cfg.max_degree), np.float32)
                indptr, indices = adj_in.indptr, adj_in.indices
                for u in range(adj_in.shape[0]):
                    nbrs = indices[indptr[u]: indptr[u + 1]]
                    if len(nbrs) > cfg.max_degree:
                        nbrs = rng.choice(nbrs, cfg.max_degree, replace=False)
                    nbr[u, : len(nbrs)] = nbrs
                    mask[u, : len(nbrs)] = 1.0
                self.rel_tables[key] = (nbr, mask)
            if plan.na.layout == "bucketed":
                self.full_buckets = {
                    key: mp.bucket_padded(
                        mp.PaddedSubgraph(nbr, mask, [key[0], key[2]]),
                        cfg.degree_buckets)
                    for key, (nbr, mask) in self.rel_tables.items()
                }
        elif kind == "instance":  # MAGNN
            self.k_eff = min(self.spec.fanout, cfg.max_instances)
            self.insts = [
                mp.enumerate_instances(self.hg, list(p), cfg.max_instances,
                                       rng=rng)
                for p in plan.metapaths
            ]
        elif kind == "gcn":
            csr = mp.build_csr(self.hg, [self.target, self.target])
            self.csr = csr
            deg = np.diff(csr.indptr)
            self.max_deg = int(deg.max()) if len(deg) else 1
            self.k_eff = min(self.spec.fanout, self.max_deg)
        else:
            raise ValueError(f"unknown NA kind {kind!r}")

    # ------------------------------------------------------------------
    # rung selection
    # ------------------------------------------------------------------
    def _clamp(self, f_cap: int, t: str) -> int:
        return min(f_cap, self.hg.node_counts[t])

    def pick_rung(self, n_targets: int, need: Dict[str, int],
                  max_rung: Optional[int] = None) -> int:
        """Smallest rung fitting the targets and every type's real rows;
        overflow falls through to the largest allowed rung (frontier
        truncation).  ``max_rung`` clamps the choice — the serve engine's
        degradation controller passes it to fan work *down* the ladder
        under pressure while staying inside the warmed rung set."""
        ladder = self.spec.ladder
        hi = (len(ladder) - 1 if max_rung is None
              else min(int(max_rung), len(ladder) - 1))
        for i, (t_cap, f_cap) in enumerate(ladder[: hi + 1]):
            if n_targets > t_cap:
                continue
            if all(n <= self._clamp(f_cap, ty) for ty, n in need.items()):
                return i
        if n_targets > max(t for t, _ in ladder[: hi + 1]):
            raise ValueError(
                f"{n_targets} targets overflow the ladder's largest "
                f"allowed t_cap {max(t for t, _ in ladder[: hi + 1])} — "
                "chunk requests (the serve engine's slot_targets does this)")
        return hi

    # ------------------------------------------------------------------
    # sampling entry points
    # ------------------------------------------------------------------
    def sample(self, targets: np.ndarray, rung: Optional[int] = None,
               max_rung: Optional[int] = None) -> SampledBatch:
        targets = np.asarray(targets, np.int64).reshape(-1)
        if len(targets) and (targets.min() < 0
                             or targets.max() >= self.n_target_type):
            raise ValueError(f"target ids out of range for type "
                             f"{self.target!r} ({self.n_target_type} nodes)")
        kind = self.plan.na.kind
        if kind == "gat":
            return self._sample_gat(targets, rung, max_rung)
        if kind == "mean":
            return self._sample_mean(targets, rung, max_rung)
        if kind == "instance":
            return self._sample_instance(targets, rung, max_rung)
        return self._sample_gcn(targets, rung, max_rung)

    def dummy_batch(self, rung: int) -> SampledBatch:
        """An all-pad batch at the rung's exact shapes — warmup compiles the
        jitted forward once per rung so serving never recompiles."""
        return self.sample(np.zeros(0, np.int64), rung=rung)

    # ------------------------------------------------------------------
    # shared pieces
    # ------------------------------------------------------------------
    def _frontier_order(self, hop_sets: List[np.ndarray],
                        exclude: np.ndarray) -> np.ndarray:
        """Frontier ids in (hop, id) order, minus ``exclude`` — the
        truncation order drops the farthest rim first."""
        seen = set(exclude.tolist())
        out: List[int] = []
        for ids in hop_sets:
            for g in np.unique(ids).tolist():
                if g not in seen:
                    seen.add(g)
                    out.append(g)
        return np.asarray(out, np.int64)

    def _meta(self, rung_i: int, targets: np.ndarray,
              tables: Dict[str, _TypeTable], index_bytes: int) -> Dict:
        frontier_rows = {
            t: int(tb.n_real - (len(targets) if t == self.target else 0))
            for t, tb in tables.items()
        }
        frontier_bytes = sum(
            rows * self.feat_dims[t] * 4 for t, rows in frontier_rows.items())
        return {
            "model": self.plan.model,
            "rung": tuple(self.spec.ladder[rung_i]),
            "rung_index": rung_i,
            "n_targets": int(len(targets)),
            "frontier_rows": int(sum(frontier_rows.values())),
            "frontier_bytes": int(frontier_bytes),
            "index_bytes": int(index_bytes),
            "truncated_rows": int(sum(tb.truncated for tb in tables.values())),
            "fanout": int(self.spec.fanout),
        }

    def _finish(self, batch: Dict, targets: np.ndarray, rung_i: int,
                tables: Dict[str, _TypeTable], index_bytes: int,
                ) -> SampledBatch:
        tt = tables[self.target]
        target_rows = (targets.copy() if tt.identity
                       else tt.relabel(targets))
        return SampledBatch(
            batch=batch,
            target_ids=targets,
            target_rows=target_rows,
            rung=tuple(self.spec.ladder[rung_i]),
            rung_index=rung_i,
            local={t: tb.ids for t, tb in tables.items()},
            meta=self._meta(rung_i, targets, tables, index_bytes),
        )

    def _row_mask(self, table: _TypeTable) -> jnp.ndarray:
        m = np.zeros(table.cap, np.float32)
        m[: table.n_real] = 1.0
        return jnp.asarray(m)

    # ------------------------------------------------------------------
    # HAN — stacked / bucketed metapath tables (target->target graphs)
    # ------------------------------------------------------------------
    def _expand_gat(self, targets: np.ndarray) -> List[np.ndarray]:
        """Per-hop frontier over the union of the metapath graphs; hop
        count = n_layers (each layer re-aggregates the same graphs)."""
        k = self.k_eff
        hop_sets: List[np.ndarray] = []
        cur = np.unique(targets)
        known = set(cur.tolist())
        for _ in range(self.plan.n_layers):
            nxt: List[np.ndarray] = []
            for sub in self.subs:
                nbr = sub.nbr[cur, :k]
                msk = sub.mask[cur, :k] > 0
                nxt.append(np.unique(nbr[msk]).astype(np.int64))
            new = (np.unique(np.concatenate(nxt)) if nxt
                   else np.zeros(0, np.int64))
            new = np.asarray([g for g in new.tolist() if g not in known],
                             np.int64)
            if len(new) == 0:
                break
            hop_sets.append(new)
            known.update(new.tolist())
            cur = new
        return hop_sets

    def _sample_gat(self, targets: np.ndarray, rung: Optional[int],
                    max_rung: Optional[int] = None) -> SampledBatch:
        cfg, plan = self.cfg, self.plan
        k = self.k_eff
        hop_sets = self._expand_gat(targets)
        frontier = self._frontier_order(hop_sets, targets)
        need = {self.target: len(targets) + len(frontier)}
        rung_i = (self.pick_rung(len(targets), need, max_rung)
                  if rung is None else rung)
        f_cap = self._clamp(self.spec.ladder[rung_i][1], self.target)
        table = _TypeTable(self.n_target_type, f_cap, targets, frontier)
        tables = {self.target: table}

        feats = table.rows(self.hg.features[self.target])
        batch: Dict = {
            "feats": {self.target: jnp.asarray(feats)},
            "feat_dims": {self.target: self.feat_dims[self.target]},
            "n_nodes": table.cap,
            "row_mask": self._row_mask(table),
        }
        index_bytes = 0
        if plan.na.layout == "bucketed":
            bks = []
            for b in self.full_buckets:
                bks.append(self._local_buckets(b, table, k))
                index_bytes += sum(r.nbytes + n.nbytes + m.nbytes
                                   for r, n, m in bks[-1])
            batch["buckets"] = [
                [(jnp.asarray(r), jnp.asarray(n), jnp.asarray(m))
                 for r, n, m in bk] for bk in bks
            ]
        else:  # stacked
            if table.identity and k == cfg.max_degree:
                nbr, mask = mp.stack_padded(self.subs)
            else:
                locs = [self._local_padded(s.nbr[:, :k], s.mask[:, :k], table,
                                           table)
                        for s in self.subs]
                nbr, mask = mp.stack_padded([
                    mp.PaddedSubgraph(n, m, list(p))
                    for (n, m), p in zip(locs, plan.metapaths)
                ])
            index_bytes += nbr.nbytes + mask.nbytes
            batch["nbr"] = jnp.asarray(nbr)
            batch["mask"] = jnp.asarray(mask)
        return self._finish(batch, targets, rung_i, tables, index_bytes)

    def _local_padded(self, nbr: np.ndarray, mask: np.ndarray,
                      dst: _TypeTable, src: _TypeTable,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Slice a full padded table to ``dst``'s local rows and relabel the
        entries into ``src``'s local ids; entries outside the local source
        set (or rung pads) mask out."""
        rows_g = dst.ids
        sub_n = nbr[rows_g]  # [n_real, K]
        sub_m = mask[rows_g].copy()
        loc = src.relabel(sub_n.reshape(-1)).reshape(sub_n.shape)
        sub_m[loc < 0] = 0.0
        loc = np.where(loc < 0, 0, loc)
        out_n = np.zeros((dst.cap, nbr.shape[1]), np.int32)
        out_m = np.zeros((dst.cap, nbr.shape[1]), np.float32)
        out_n[: len(rows_g)] = loc
        out_m[: len(rows_g)] = sub_m
        return out_n, out_m

    def _local_buckets(self, full: mp.DegreeBuckets, table: _TypeTable,
                       k: int) -> List[Tuple[np.ndarray, np.ndarray,
                                             np.ndarray]]:
        """Rung-shaped degree buckets: full-graph caps (static), every
        bucket padded to ``table.cap`` rows with out-of-range pad row_ids
        (the scatter drops them).  Identity + full fan-out reuses the full
        tables verbatim — the bucketed parity path."""
        if table.identity and k >= max(n.shape[1] for n in full.nbr):
            return [(full.row_ids[i], full.nbr[i], full.mask[i])
                    for i in range(full.n_buckets)]
        # rebuild the full padded view, then re-bin local rows by the full
        # caps so bucket shapes stay rung-static
        caps = [n.shape[1] for n in full.nbr]
        n_full = full.n_nodes
        nbr_f = np.zeros((n_full, max(caps)), np.int32)
        mask_f = np.zeros((n_full, max(caps)), np.float32)
        for i in range(full.n_buckets):
            rows, cap = full.row_ids[i], caps[i]
            nbr_f[rows, :cap] = full.nbr[i]
            mask_f[rows, :cap] = full.mask[i]
        kk = min(k, max(caps))
        loc_n, loc_m = self._local_padded(nbr_f[:, :kk], mask_f[:, :kk],
                                          table, table)
        deg = loc_m.sum(axis=1)
        out = []
        assigned = np.zeros(table.cap, bool)
        for cap in caps:
            cap_k = min(cap, kk)
            rows = np.flatnonzero(~assigned & (deg <= cap_k)
                                  & (np.arange(table.cap) < table.n_real))
            assigned[rows] = True
            row_ids = np.full(table.cap, table.cap, np.int32)  # OOB pads
            row_ids[: len(rows)] = rows
            b_n = np.zeros((table.cap, cap_k), np.int32)
            b_m = np.zeros((table.cap, cap_k), np.float32)
            b_n[: len(rows)] = loc_n[rows, :cap_k]
            b_m[: len(rows)] = loc_m[rows, :cap_k]
            out.append((row_ids, b_n, b_m))
        return out

    # ------------------------------------------------------------------
    # RGCN — per-relation padded (or bucketed) tables, typed k-hop ball
    # ------------------------------------------------------------------
    def _sample_mean(self, targets: np.ndarray, rung: Optional[int],
                     max_rung: Optional[int] = None) -> SampledBatch:
        cfg, plan = self.cfg, self.plan
        k = self.k_eff
        # typed frontier expansion: per hop, every relation (s, r, d) pulls
        # the in-neighbors (type s) of the currently-needed rows of type d
        per_type_hops: Dict[str, List[np.ndarray]] = {
            t: [] for t in self.hg.node_counts}
        known: Dict[str, set] = {t: set() for t in self.hg.node_counts}
        cur: Dict[str, np.ndarray] = {
            t: np.zeros(0, np.int64) for t in self.hg.node_counts}
        cur[self.target] = np.unique(targets)
        known[self.target].update(cur[self.target].tolist())
        for _ in range(plan.n_layers):
            nxt: Dict[str, List[np.ndarray]] = {
                t: [] for t in self.hg.node_counts}
            for key in self.rel_keys:
                s, _, d = key
                rows = cur[d]
                if len(rows) == 0:
                    continue
                nbr, mask = self.rel_tables[key]
                sub_n, sub_m = nbr[rows, :k], mask[rows, :k] > 0
                nxt[s].append(np.unique(sub_n[sub_m]).astype(np.int64))
            new_cur: Dict[str, np.ndarray] = {}
            for t in self.hg.node_counts:
                cand = (np.unique(np.concatenate(nxt[t])) if nxt[t]
                        else np.zeros(0, np.int64))
                new = np.asarray(
                    [g for g in cand.tolist() if g not in known[t]], np.int64)
                if len(new):
                    per_type_hops[t].append(new)
                    known[t].update(new.tolist())
                new_cur[t] = new
            cur = new_cur
            if not any(len(v) for v in cur.values()):
                break

        tables: Dict[str, _TypeTable] = {}
        need: Dict[str, int] = {}
        for t in self.hg.node_counts:
            tgt = targets if t == self.target else np.zeros(0, np.int64)
            frontier = self._frontier_order(per_type_hops[t], tgt)
            need[t] = len(tgt) + len(frontier)
        rung_i = (self.pick_rung(len(targets), need, max_rung)
                  if rung is None else rung)
        f_cap = self.spec.ladder[rung_i][1]
        for t in self.hg.node_counts:
            tgt = targets if t == self.target else np.zeros(0, np.int64)
            frontier = self._frontier_order(per_type_hops[t], tgt)
            tables[t] = _TypeTable(self.hg.node_counts[t],
                                   self._clamp(f_cap, t), tgt, frontier)

        batch: Dict = {
            "feats": {t: jnp.asarray(tables[t].rows(self.hg.features[t]))
                      for t in self.hg.features},
            "counts": {t: tables[t].cap for t in self.hg.node_counts},
            "feat_dims": dict(self.feat_dims),
            "rels": {},
        }
        index_bytes = 0
        for key in self.rel_keys:
            s, _, d = key
            if plan.na.layout == "bucketed":
                bk = self._local_buckets_rel(key, tables[d], tables[s], k)
                index_bytes += sum(r.nbytes + n.nbytes + m.nbytes
                                   for r, n, m in bk)
                batch["rels"][key] = [
                    (jnp.asarray(r), jnp.asarray(n), jnp.asarray(m))
                    for r, n, m in bk
                ]
            else:
                nbr, mask = self.rel_tables[key]
                if (tables[d].identity and tables[s].identity
                        and k == cfg.max_degree):
                    loc_n, loc_m = nbr, mask
                else:
                    loc_n, loc_m = self._local_padded(
                        nbr[:, :k], mask[:, :k], tables[d], tables[s])
                index_bytes += loc_n.nbytes + loc_m.nbytes
                batch["rels"][key] = (jnp.asarray(loc_n), jnp.asarray(loc_m))
        return self._finish(batch, targets, rung_i, tables, index_bytes)

    def _local_buckets_rel(self, key, dst: _TypeTable, src: _TypeTable,
                           k: int) -> List[Tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]]:
        full = self.full_buckets[key]
        if (dst.identity and src.identity
                and k >= max(n.shape[1] for n in full.nbr)):
            return [(full.row_ids[i], full.nbr[i], full.mask[i])
                    for i in range(full.n_buckets)]
        caps = [n.shape[1] for n in full.nbr]
        nbr, mask = self.rel_tables[key]
        kk = min(k, max(caps))
        loc_n, loc_m = self._local_padded(nbr[:, :kk], mask[:, :kk], dst, src)
        deg = loc_m.sum(axis=1)
        out = []
        assigned = np.zeros(dst.cap, bool)
        for cap in caps:
            cap_k = min(cap, kk)
            rows = np.flatnonzero(~assigned & (deg <= cap_k)
                                  & (np.arange(dst.cap) < dst.n_real))
            assigned[rows] = True
            row_ids = np.full(dst.cap, dst.cap, np.int32)  # OOB pads drop
            row_ids[: len(rows)] = rows
            b_n = np.zeros((dst.cap, cap_k), np.int32)
            b_m = np.zeros((dst.cap, cap_k), np.float32)
            b_n[: len(rows)] = loc_n[rows, :cap_k]
            b_m[: len(rows)] = loc_m[rows, :cap_k]
            out.append((row_ids, b_n, b_m))
        return out

    # ------------------------------------------------------------------
    # MAGNN — instance tables; frontier = instance node sets
    # ------------------------------------------------------------------
    def _sample_instance(self, targets: np.ndarray, rung: Optional[int],
                         max_rung: Optional[int] = None) -> SampledBatch:
        plan, cfg = self.plan, self.cfg
        i_cap = self.k_eff  # instances per target (the MAGNN fan-out knob)
        # target-type rows that need REAL instance rows: the requested
        # targets plus, per extra layer, the target-type nodes appearing in
        # already-kept instances (layer l's gathers read layer l-1's
        # updated tables)
        rows = np.unique(targets)
        known = set(rows.tolist())
        tgt_hops: List[np.ndarray] = []
        cur = rows
        for _ in range(plan.n_layers - 1):
            nxt: List[np.ndarray] = []
            for ib, p in zip(self.insts, plan.metapaths):
                nodes = ib.nodes[cur, :i_cap]  # [n, I, L]
                msk = ib.mask[cur, :i_cap] > 0
                for j, ty in enumerate(p):
                    if ty == self.target:
                        nxt.append(np.unique(nodes[:, :, j][msk])
                                   .astype(np.int64))
            cand = (np.unique(np.concatenate(nxt)) if nxt
                    else np.zeros(0, np.int64))
            new = np.asarray([g for g in cand.tolist() if g not in known],
                             np.int64)
            if len(new) == 0:
                break
            tgt_hops.append(new)
            known.update(new.tolist())
            cur = new
        inst_rows = (np.concatenate([np.unique(targets)] + tgt_hops)
                     if len(targets) or tgt_hops else np.zeros(0, np.int64))

        # per-type frontiers: every node on a kept instance
        per_type: Dict[str, List[np.ndarray]] = {
            t: [] for t in self.hg.node_counts}
        for ib, p in zip(self.insts, plan.metapaths):
            if len(inst_rows) == 0:
                continue
            nodes = ib.nodes[inst_rows, :i_cap]
            msk = ib.mask[inst_rows, :i_cap] > 0
            for j, ty in enumerate(p):
                per_type[ty].append(
                    np.unique(nodes[:, :, j][msk]).astype(np.int64))

        tables: Dict[str, _TypeTable] = {}
        need: Dict[str, int] = {}
        types_used = {ty for p in plan.metapaths for ty in p} | {self.target}
        fr: Dict[str, np.ndarray] = {}
        for t in sorted(types_used):
            tgt = targets if t == self.target else np.zeros(0, np.int64)
            hops = ([np.asarray(sorted(set(np.concatenate(per_type[t]).tolist())
                                       if per_type[t] else []), np.int64)]
                    if per_type[t] else [])
            fr[t] = self._frontier_order(hops, tgt)
            need[t] = len(tgt) + len(fr[t])
        rung_i = (self.pick_rung(len(targets), need, max_rung)
                  if rung is None else rung)
        f_cap = self.spec.ladder[rung_i][1]
        for t in sorted(types_used):
            tgt = targets if t == self.target else np.zeros(0, np.int64)
            tables[t] = _TypeTable(self.hg.node_counts[t],
                                   self._clamp(f_cap, t), tgt, fr[t])

        tt = tables[self.target]
        batch: Dict = {
            "feats": {t: jnp.asarray(tables[t].rows(self.hg.features[t]))
                      for t in sorted(types_used)},
            "feat_dims": {t: self.feat_dims[t] for t in sorted(types_used)},
            "n_nodes": tt.cap,
            "row_mask": self._row_mask(tt),
        }
        index_bytes = 0
        instances = []
        for ib, p in zip(self.insts, plan.metapaths):
            if tt.identity and i_cap == cfg.max_instances and all(
                    tables[ty].identity for ty in p):
                nodes, mask = ib.nodes, ib.mask
            else:
                nodes = np.zeros((tt.cap, i_cap, len(p)), np.int32)
                mask = np.zeros((tt.cap, i_cap), np.float32)
                src_rows = ib.nodes[tt.ids, :i_cap]  # [n_real, I, L]
                src_mask = ib.mask[tt.ids, :i_cap].copy()
                for j, ty in enumerate(p):
                    loc = tables[ty].relabel(src_rows[:, :, j].reshape(-1))
                    loc = loc.reshape(src_rows.shape[:2])
                    # an instance touching a truncated node drops entirely
                    src_mask[(loc < 0) & (src_mask > 0)] = 0.0
                    nodes[: tt.n_real, :, j] = np.where(loc < 0, 0, loc)
                mask[: tt.n_real] = src_mask
                nodes[mask == 0] = 0
            index_bytes += nodes.nbytes + mask.nbytes
            instances.append((jnp.asarray(nodes), jnp.asarray(mask)))
        batch["instances"] = instances
        return self._finish(batch, targets, rung_i, tables, index_bytes)

    # ------------------------------------------------------------------
    # GCN — homogeneous edge list, 2 aggregation hops per layer
    # ------------------------------------------------------------------
    def _sample_gcn(self, targets: np.ndarray, rung: Optional[int],
                    max_rung: Optional[int] = None) -> SampledBatch:
        plan = self.plan
        k = self.k_eff
        indptr, indices = self.csr.indptr, self.csr.indices
        cur = np.unique(targets)
        known = set(cur.tolist())
        hop_sets: List[np.ndarray] = []
        for _ in range(2 * plan.n_layers):  # 2 aggregations per layer
            nxt: List[np.ndarray] = []
            for g in cur.tolist():
                nbrs = indices[indptr[g]: indptr[g] + min(
                    k, indptr[g + 1] - indptr[g])]
                nxt.append(nbrs.astype(np.int64))
            cand = (np.unique(np.concatenate(nxt)) if nxt
                    else np.zeros(0, np.int64))
            new = np.asarray([g for g in cand.tolist() if g not in known],
                             np.int64)
            if len(new) == 0:
                break
            hop_sets.append(new)
            known.update(new.tolist())
            cur = new
        frontier = self._frontier_order(hop_sets, targets)
        need = {self.target: len(targets) + len(frontier)}
        rung_i = (self.pick_rung(len(targets), need, max_rung)
                  if rung is None else rung)
        f_cap = self._clamp(self.spec.ladder[rung_i][1], self.target)
        table = _TypeTable(self.n_target_type, f_cap, targets, frontier)

        if table.identity and k == self.max_deg:
            seg, idx = (np.repeat(np.arange(table.cap, dtype=np.int32),
                                  np.diff(indptr)),
                        indices.astype(np.int32))
        else:
            e_cap = table.cap * max(k, 1)
            seg = np.full(e_cap, table.cap, np.int32)  # OOB segments drop
            idx = np.zeros(e_cap, np.int32)
            e = 0
            for u_loc in range(table.n_real):
                g = table.ids[u_loc]
                nbrs = indices[indptr[g]: indptr[g] + min(
                    k, indptr[g + 1] - indptr[g])]
                loc = table.relabel(nbrs.astype(np.int64))
                loc = loc[loc >= 0][: k]
                seg[e: e + len(loc)] = u_loc
                idx[e: e + len(loc)] = loc
                e += len(loc)
        batch: Dict = {
            "x": jnp.asarray(table.rows(self.hg.features[self.target])),
            "seg": jnp.asarray(seg),
            "idx": jnp.asarray(idx),
            "n_nodes": table.cap,
            "feat_dim": self.feat_dims[self.target],
        }
        return self._finish(batch, targets, rung_i, {self.target: table},
                            int(seg.nbytes + idx.nbytes))
