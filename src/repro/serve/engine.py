"""Serving engines.

``ServeEngine`` — LM slot-based continuous batching over the prefill/decode
step functions.  Requests occupy fixed batch slots; each decode step advances
every active slot by one token.  Finished slots (EOS or max_tokens) are
refilled from the queue without stopping the decode loop — decode-32k-style
serving as the paper's shapes require.  Sampling: greedy or temperature.

``HGNNInferEngine`` — HGNN inference driven by a :class:`StagePlan`: the
engine holds the stage-graph executor (not a model class), serves the jitted
forward, and exposes the per-stage characterization records from the exact
code path it serves.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.nn import transformer as tf


class HGNNInferEngine:
    """Plan-driven HGNN serving.

    Consumes a :class:`repro.core.pipeline.StageGraphExecutor` (built from a
    :class:`repro.core.plan.StagePlan`) plus the prepared params/batch —
    typically the fields of ``launch.serve.build_hgnn_infer``'s result.  The
    executor resolves layout / kernel / sharding dispatch; the engine adds
    the serving loop and the characterization hook, so the stage breakdown
    reported to operators comes from the same plan that serves traffic.
    """

    def __init__(self, executor, params, batch, fn=None):
        self.executor = executor
        self.plan = executor.plan
        self.params = params
        self.batch = batch
        self.fn = fn if fn is not None else jax.jit(executor.forward)

    def infer(self) -> jax.Array:
        """One full forward over the prepared batch -> logits."""
        return self.fn(self.params, self.batch)

    def characterize(self, n_chips: int = 1) -> Dict[str, Dict]:
        """Per-stage (FP/NA/SA/head) FLOPs / HBM bytes / roofline records
        via ``core/characterize.py`` — the paper's Fig. 3 breakdown from the
        serving code path."""
        return self.executor.stage_records(self.params, self.batch,
                                           n_chips=n_chips)["stages"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [T] int32
    max_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 8,
                 max_len: int = 512, rng_seed: int = 0, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.key(rng_seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: tf.lm_decode_step(p, cfg, t, c, pos))

    def _sample(self, logits: jax.Array, temps: Optional[jax.Array]) -> jax.Array:
        """Per-slot sampling: each request in the wave keeps its own
        temperature (greedy where <= 0, categorical otherwise).  ``temps``
        is the device array built ONCE per wave by ``_run_wave`` — None
        means an all-greedy wave, so the per-token loop never re-uploads or
        re-reduces wave-constant facts."""
        greedy = jnp.argmax(logits, axis=-1)
        if temps is None:
            return greedy
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps, 1e-6)[:, None], axis=-1)
        return jnp.where(temps > 0.0, sampled, greedy)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Simple batched generation: pad prompts to a common length, prefill
        once, then decode lock-step (same-length prompts per wave)."""
        out: List[Request] = []
        for wave_start in range(0, len(requests), self.slots):
            wave = requests[wave_start: wave_start + self.slots]
            out.extend(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(wave)
        t0 = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, t0), np.int32)
        for i, r in enumerate(wave):
            toks[i, t0 - len(r.prompt):] = r.prompt  # left-pad
        logits, pf_caches = tf.lm_prefill(self.params, cfg, jnp.asarray(toks))
        caches = tf.graft_prefill_caches(
            cfg, tf.init_kv_caches(cfg, b, self.max_len), pf_caches, t0)
        max_new = max(r.max_tokens for r in wave)
        temps_host = np.array([r.temperature for r in wave], np.float32)
        temps = (jnp.asarray(temps_host) if (temps_host > 0).any() else None)
        cur = self._sample(logits[:, 0], temps)
        outs = [[int(cur[i])] for i in range(b)]
        done = np.zeros(b, bool)
        for step in range(1, max_new):
            pos = jnp.int32(t0 + step - 1)
            logits, caches = self._decode(self.params, cur[:, None], caches, pos)
            cur = self._sample(logits[:, 0], temps)
            for i in range(b):
                if done[i] or step >= wave[i].max_tokens:
                    done[i] = True
                    continue
                t = int(cur[i])
                outs[i].append(t)
                if t == self.eos_id:
                    done[i] = True
            if done.all():
                break
        for r, o in zip(wave, outs):
            r.out_tokens = o[: r.max_tokens]
        return wave
