"""Serving engines.

``ServeEngine`` — LM slot-based continuous batching over the prefill/decode
step functions.  Requests occupy fixed batch slots; each decode step advances
every active slot by one token.  Finished slots (EOS or max_tokens) are
refilled from the queue without stopping the decode loop — decode-32k-style
serving as the paper's shapes require.  Sampling: greedy or temperature.

``HGNNInferEngine`` — HGNN inference driven by a :class:`StagePlan`: the
engine holds the stage-graph executor (not a model class), serves the jitted
forward, and exposes the per-stage characterization records from the exact
code path it serves.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.nn import transformer as tf


class HGNNInferEngine:
    """Plan-driven HGNN serving.

    Consumes a :class:`repro.core.pipeline.StageGraphExecutor` (built from a
    :class:`repro.core.plan.StagePlan`) plus the prepared params/batch —
    typically the fields of ``launch.serve.build_hgnn_infer``'s result.  The
    executor resolves layout / kernel / sharding dispatch; the engine adds
    the serving loop and the characterization hook, so the stage breakdown
    reported to operators comes from the same plan that serves traffic.
    """

    def __init__(self, executor, params, batch, fn=None):
        self.executor = executor
        self.plan = executor.plan
        self.params = params
        self.batch = batch
        self.fn = fn if fn is not None else jax.jit(executor.forward)

    def infer(self) -> jax.Array:
        """One full forward over the prepared batch -> logits."""
        return self.fn(self.params, self.batch)

    def characterize(self, n_chips: int = 1) -> Dict[str, Dict]:
        """Per-stage (FP/NA/SA/head) FLOPs / HBM bytes / roofline records
        via ``core/characterize.py`` — the paper's Fig. 3 breakdown from the
        serving code path."""
        return self.executor.stage_records(self.params, self.batch,
                                           n_chips=n_chips)["stages"]


@dataclasses.dataclass
class HGNNRequest:
    """One HGNN inference request: classify ``targets`` (global target-type
    vertex ids).  ``logits`` fills in request order as the engine's slot
    steps complete chunks of the request."""
    targets: np.ndarray  # [n] int64, global ids of the plan's target type
    logits: Optional[np.ndarray] = None  # [n, n_classes] once served
    _done: int = 0  # host cursor: rows < _done are already scattered

    @property
    def finished(self) -> bool:
        return self.logits is not None and self._done >= len(self.targets)


class HGNNServeEngine:
    """Slot-based continuous batching for HGNN requests.

    The LM ``ServeEngine``'s serving discipline ported to the request path:
    requests occupy fixed batch slots; each step every active slot
    contributes up to ``slot_targets`` of its remaining target vertices to a
    union minibatch, the sampler extracts one bucketed subgraph for the
    union, a single jitted forward serves it, and the logits scatter back
    per request through ``SampledBatch.target_rows`` (the relabel inverse).
    Finished slots refill from the queue without stopping the step loop, so
    a mixed-size queue never idles a slot while work remains.

    ``warmup()`` compiles one entry per ladder rung; afterwards
    ``stats["compiles_after_warmup"]`` must stay 0 on a single device (the
    ladder is the whole shape space).  Partitioned plans re-partition the
    sampled batch each step (host relabeling chooses data-dependent halo
    shapes, so partitioned serving accepts recompiles — same convention as
    the partition benchmarks).
    """

    def __init__(self, executor, params, sampler, slots: int = 8,
                 slot_targets: int = 4, fn=None):
        self.executor = executor
        self.plan = executor.plan
        self.params = params
        self.sampler = sampler
        self.slots = slots
        self.slot_targets = slot_targets
        self.fn = fn if fn is not None else jax.jit(executor.forward)
        max_t = max(t for t, _ in sampler.ladder)
        if slots * slot_targets > max_t:
            raise ValueError(
                f"slots*slot_targets={slots * slot_targets} exceeds the "
                f"largest ladder rung's target cap {max_t}; widen the "
                "ladder or shrink the slot plan")
        self._warm_compiles: Optional[int] = None
        self.step_log: List[Dict] = []
        self.last_sb = None

    def _forward_batch(self, batch: Dict) -> Dict:
        if self.plan.partition is not None:
            from repro.dist.partition import partition_batch
            return partition_batch(self.plan, batch)
        return batch

    def warmup(self) -> int:
        """Compile every ladder rung on a dummy batch; snapshot the jit
        cache size so ``stats`` can report post-warmup recompiles."""
        for i in range(len(self.sampler.ladder)):
            sb = self.sampler.dummy_batch(i)
            jax.block_until_ready(
                self.fn(self.params, self._forward_batch(sb.batch)))
        self._warm_compiles = self.fn._cache_size()
        return self._warm_compiles

    def serve(self, requests: List[HGNNRequest]) -> List[HGNNRequest]:
        """Run the slot loop until every request's logits are complete."""
        import collections
        import time

        q = collections.deque(requests)
        active: List[Optional[HGNNRequest]] = [None] * self.slots
        self.step_log = []
        while q or any(r is not None for r in active):
            # refill: finished slots take the next queued request
            for s in range(self.slots):
                while active[s] is None and q:
                    r = q.popleft()
                    if len(r.targets) == 0:  # degenerate: nothing to serve
                        r.logits = np.zeros((0, 0), np.float32)
                        continue
                    active[s] = r
            chunks = []  # (request, start_row_in_request, ids)
            for r in active:
                if r is None:
                    continue
                ids = r.targets[r._done: r._done + self.slot_targets]
                chunks.append((r, r._done, np.asarray(ids, np.int64)))
            if not chunks:  # queue held only degenerate requests
                continue
            ids = np.concatenate([c[2] for c in chunks])
            t0 = time.perf_counter()
            sb = self.sampler.sample(ids)
            out = np.asarray(self.fn(self.params,
                                     self._forward_batch(sb.batch)))
            rows = out[sb.target_rows]
            wall = time.perf_counter() - t0
            off = 0
            for r, start, cids in chunks:
                n = len(cids)
                if r.logits is None:
                    r.logits = np.zeros((len(r.targets), rows.shape[1]),
                                        rows.dtype)
                r.logits[start: start + n] = rows[off: off + n]
                r._done = start + n
                off += n
            for s in range(self.slots):
                if active[s] is not None and active[s].finished:
                    active[s] = None
            self.step_log.append({
                "active_slots": len(chunks),
                "queue_len": len(q),
                "n_targets": int(sb.n_targets),
                "rung_index": int(sb.rung_index),
                "frontier_bytes": float(sb.meta["frontier_bytes"]),
                "truncated_rows": int(sb.meta["truncated_rows"]),
                "wall_s": wall,
            })
            self.last_sb = sb
        return requests

    def stats(self) -> Dict:
        """Deterministic serving counters (walls reported, never gated)."""
        rung_hits: Dict[int, int] = {}
        for e in self.step_log:
            rung_hits[e["rung_index"]] = rung_hits.get(e["rung_index"], 0) + 1
        compiles = (self.fn._cache_size() - self._warm_compiles
                    if self._warm_compiles is not None else -1)
        walls = [e["wall_s"] for e in self.step_log]
        return {
            "steps": len(self.step_log),
            "rung_hits": {int(k): int(v)
                          for k, v in sorted(rung_hits.items())},
            "frontier_bytes": float(
                sum(e["frontier_bytes"] for e in self.step_log)),
            "truncated_rows": int(
                sum(e["truncated_rows"] for e in self.step_log)),
            "compiles_after_warmup": int(compiles),
            "wall_total_s": float(sum(walls)),
            "wall_mean_ms": float(1e3 * np.mean(walls)) if walls else 0.0,
        }


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [T] int32
    max_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 8,
                 max_len: int = 512, rng_seed: int = 0, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.key(rng_seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: tf.lm_decode_step(p, cfg, t, c, pos))

    def _sample(self, logits: jax.Array, temps: Optional[jax.Array]) -> jax.Array:
        """Per-slot sampling: each request in the wave keeps its own
        temperature (greedy where <= 0, categorical otherwise).  ``temps``
        is the device array built ONCE per wave by ``_run_wave`` — None
        means an all-greedy wave, so the per-token loop never re-uploads or
        re-reduces wave-constant facts."""
        greedy = jnp.argmax(logits, axis=-1)
        if temps is None:
            return greedy
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps, 1e-6)[:, None], axis=-1)
        return jnp.where(temps > 0.0, sampled, greedy)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Simple batched generation: pad prompts to a common length, prefill
        once, then decode lock-step (same-length prompts per wave)."""
        out: List[Request] = []
        for wave_start in range(0, len(requests), self.slots):
            wave = requests[wave_start: wave_start + self.slots]
            out.extend(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(wave)
        t0 = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, t0), np.int32)
        for i, r in enumerate(wave):
            toks[i, t0 - len(r.prompt):] = r.prompt  # left-pad
        logits, pf_caches = tf.lm_prefill(self.params, cfg, jnp.asarray(toks))
        caches = tf.graft_prefill_caches(
            cfg, tf.init_kv_caches(cfg, b, self.max_len), pf_caches, t0)
        max_new = max(r.max_tokens for r in wave)
        temps_host = np.array([r.temperature for r in wave], np.float32)
        temps = (jnp.asarray(temps_host) if (temps_host > 0).any() else None)
        cur = self._sample(logits[:, 0], temps)
        outs = [[int(cur[i])] for i in range(b)]
        done = np.zeros(b, bool)
        for step in range(1, max_new):
            pos = jnp.int32(t0 + step - 1)
            logits, caches = self._decode(self.params, cur[:, None], caches, pos)
            cur = self._sample(logits[:, 0], temps)
            for i in range(b):
                if done[i] or step >= wave[i].max_tokens:
                    done[i] = True
                    continue
                t = int(cur[i])
                outs[i].append(t)
                if t == self.eos_id:
                    done[i] = True
            if done.all():
                break
        for r, o in zip(wave, outs):
            r.out_tokens = o[: r.max_tokens]
        return wave
