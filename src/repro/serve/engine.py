"""Serving engines.

``ServeEngine`` — LM slot-based continuous batching over the prefill/decode
step functions.  Requests occupy fixed batch slots; each decode step advances
every active slot by one token.  Finished slots (EOS or max_tokens) are
refilled from the queue without stopping the decode loop — decode-32k-style
serving as the paper's shapes require.  Sampling: greedy or temperature.

``HGNNInferEngine`` — HGNN inference driven by a :class:`StagePlan`: the
engine holds the stage-graph executor (not a model class), serves the jitted
forward, and exposes the per-stage characterization records from the exact
code path it serves.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.nn import transformer as tf
from repro.serve import resilience
from repro.serve.resilience import (
    FAILED, OK, PARTIAL, AdmissionController, DegradationController,
    ResilienceConfig, RetryPolicy, StepFailure, finalize_request)


class HGNNInferEngine:
    """Plan-driven HGNN serving.

    Consumes a :class:`repro.core.pipeline.StageGraphExecutor` (built from a
    :class:`repro.core.plan.StagePlan`) plus the prepared params/batch —
    typically the fields of ``launch.serve.build_hgnn_infer``'s result.  The
    executor resolves layout / kernel / sharding dispatch; the engine adds
    the serving loop and the characterization hook, so the stage breakdown
    reported to operators comes from the same plan that serves traffic.
    """

    def __init__(self, executor, params, batch, fn=None):
        self.executor = executor
        self.plan = executor.plan
        self.params = params
        self.batch = batch
        self.fn = fn if fn is not None else jax.jit(executor.forward)

    def infer(self) -> jax.Array:
        """One full forward over the prepared batch -> logits."""
        return self.fn(self.params, self.batch)

    def characterize(self, n_chips: int = 1) -> Dict[str, Dict]:
        """Per-stage (FP/NA/SA/head) FLOPs / HBM bytes / roofline records
        via ``core/characterize.py`` — the paper's Fig. 3 breakdown from the
        serving code path."""
        return self.executor.stage_records(self.params, self.batch,
                                           n_chips=n_chips)["stages"]


@dataclasses.dataclass
class HGNNRequest:
    """One HGNN inference request: classify ``targets`` (global target-type
    vertex ids).

    ``serve`` leaves every request in a terminal ``status``
    (``OK`` / ``PARTIAL`` / ``REJECTED`` / ``FAILED`` — see
    ``repro.serve.resilience``) with ``logits`` rows for exactly the target
    ids named by ``served`` (all of ``targets`` when ``OK``; the rows
    completed before the deadline/failure otherwise; always ``n_classes``
    wide, so downstream concatenation over mixed-status requests is
    well-formed).  ``deadline_ms`` overrides the engine-wide default."""
    targets: np.ndarray  # [n] integer, global ids of the plan's target type
    logits: Optional[np.ndarray] = None  # [n_served, n_classes] when done
    deadline_ms: Optional[float] = None  # per-request deadline override
    status: str = "NEW"
    error: Optional[str] = None          # reject/failure reason
    served: Optional[np.ndarray] = None  # target ids the logits rows answer
    _done: int = 0  # host cursor into _serve_ids: rows < _done are served
    _serve_ids: Optional[np.ndarray] = None  # admission's deduped id view
    _inv: Optional[np.ndarray] = None        # original row -> _serve_ids row
    _buf: Optional[np.ndarray] = None        # [len(_serve_ids), C] working
    _deadline: Optional[float] = None        # absolute perf_counter deadline

    @property
    def finished(self) -> bool:
        return self.status in resilience.TERMINAL


class _SamplerPrefetcher:
    """Async host-side sampler refill — one of the stage-graph schedule's
    three overlap sources (``ScheduleSpec.prefetch``).

    While the device executes step ``t``'s jitted forward, a single worker
    thread samples the *predicted* step ``t+1`` union batch
    (``HGNNServeEngine._predict_next`` simulates the engine's own
    slot/queue advance).  The prediction misses whenever the simulation is
    wrong — deadline expiry, a degradation shift, a failed step — in which
    case :meth:`take` discards the speculative batch and the engine falls
    back to the synchronous sampler.  Always correct regardless of hit
    rate: ``HGNNSampler.sample`` is a pure function of ``(ids, rung)``
    (its RNG only seeds the one-time table build), so a discarded
    speculative call perturbs nothing.
    """

    def __init__(self, sampler):
        from concurrent.futures import ThreadPoolExecutor

        self.sampler = sampler
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._future = None
        self._key = None
        self.counters: Dict[str, int] = {
            "issued": 0, "hits": 0, "mispredicts": 0, "cold": 0}

    @staticmethod
    def _mk_key(ids: np.ndarray, rung_limit: int):
        return (np.asarray(ids, np.int64).tobytes(), int(rung_limit))

    def submit(self, ids: np.ndarray, rung_limit: int) -> None:
        """Start sampling a speculative next-step batch (at most one in
        flight; a still-pending speculation keeps its slot)."""
        if self._future is not None:
            return
        self._key = self._mk_key(ids, rung_limit)
        self.counters["issued"] += 1
        self._future = self._pool.submit(
            self.sampler.sample, np.asarray(ids, np.int64),
            max_rung=int(rung_limit))

    def take(self, ids: np.ndarray, rung_limit: int):
        """The prefetched batch iff it answers exactly ``(ids,
        rung_limit)``; ``None`` (sync fallback) otherwise."""
        fut, self._future = self._future, None
        if fut is None:
            self.counters["cold"] += 1
            return None
        try:
            sb = fut.result()
        except Exception:  # noqa: BLE001 — sync retry path re-raises it
            sb = None
        if sb is None or self._key != self._mk_key(ids, rung_limit):
            self.counters["mispredicts"] += 1
            return None
        self.counters["hits"] += 1
        return sb

    def drain(self) -> None:
        """Block on any in-flight speculation and stop the worker — serve
        teardown must not leak a running sampler thread, whether the loop
        ended clean, deadline-expired every request, or failed over."""
        if self._future is not None:
            try:
                self._future.result()
            except Exception:  # noqa: BLE001 — speculation is disposable
                pass
            self._future = None
        self._pool.shutdown(wait=True)


class HGNNServeEngine:
    """Slot-based continuous batching for HGNN requests.

    The LM ``ServeEngine``'s serving discipline ported to the request path:
    requests occupy fixed batch slots; each step every active slot
    contributes up to ``slot_targets`` of its remaining target vertices to a
    union minibatch, the sampler extracts one bucketed subgraph for the
    union, a single jitted forward serves it, and the logits scatter back
    per request through ``SampledBatch.target_rows`` (the relabel inverse).
    Finished slots refill from the queue without stopping the step loop, so
    a mixed-size queue never idles a slot while work remains.

    ``warmup()`` compiles one entry per ladder rung; afterwards
    ``stats["compiles_after_warmup"]`` must stay 0 — partitioned plans
    included (the ladder is the whole shape space).  Partitioned plans
    re-partition the sampled batch each step, and the minimal host
    relabeling chooses data-dependent owned/halo table widths, so the
    engine serves a ``static_shapes`` copy of the partition spec: every
    per-type table pads to assignment-independent capacities
    (``n_max = ceil(n/k)``, ``h_max = n``), making the partitioned shapes a
    pure function of the rung and killing the per-step re-trace.

    Resilience (``repro.serve.resilience`` policies, threaded through the
    slot loop): admission control with structured per-request statuses,
    per-request deadlines (expired requests complete ``PARTIAL`` with the
    rows served so far), SLO-driven degradation that shrinks the per-slot
    chunk and clamps the rung choice *inside* the warmed ladder, bounded
    retry-with-backoff around the sampler and the jitted forward (failing
    only the affected slots' requests on persistent errors), and — on a
    partitioned plan — failover that re-partitions subsequent batches over
    the surviving partitions when ``injector`` reports a partition loss.
    """

    def __init__(self, executor, params, sampler, slots: int = 8,
                 slot_targets: int = 4, fn=None,
                 resilience_cfg: Optional[ResilienceConfig] = None,
                 injector=None):
        self.executor = executor
        self.plan = executor.plan
        self.params = params
        self.sampler = sampler
        self.slots = slots
        self.slot_targets = slot_targets
        self.fn = fn if fn is not None else jax.jit(executor.forward)
        max_t = max(t for t, _ in sampler.ladder)
        if slots * slot_targets > max_t:
            raise ValueError(
                f"slots*slot_targets={slots * slot_targets} exceeds the "
                f"largest ladder rung's target cap {max_t}; widen the "
                "ladder or shrink the slot plan")
        self.res = (resilience_cfg if resilience_cfg is not None
                    else ResilienceConfig())
        self.injector = injector
        self.n_classes = int(executor.cfg.n_classes)
        # failover target: partition loss swaps in a survivors-only spec.
        # Partitioned serving always pins static per-type table shapes —
        # see the class docstring (compiles_after_warmup == 0).
        self._serve_plan = self.plan
        if self.plan.partition is not None:
            self._serve_plan = dataclasses.replace(
                self.plan, partition=dataclasses.replace(
                    self.plan.partition, static_shapes=True))
        self._warm_compiles: Optional[int] = None
        self.step_log: List[Dict] = []
        self.last_sb = None
        # residency: live per-type hot-row caches over the sampled frontier
        # (repro.core.residency.HotRowCache).  Keyed by GLOBAL vertex ids and
        # owned by the engine — not the per-step batch — so cache state is
        # untouched by rung changes, degradation clamps, and partition
        # failover, and the jitted forward's shapes never see the cache
        # (compiles_after_warmup stays 0).
        self.caches: Optional[Dict] = None
        if self.plan.residency is not None:
            from repro.core.residency import HotRowCache, graph_degrees

            cap = self.plan.residency.cache_rows
            self.caches = {t: HotRowCache(cap, d)
                           for t, d in graph_degrees(sampler.hg).items()}
        self._fresh_policies()

    def _fresh_policies(self) -> None:
        """Per-serve policy state (counters reset each ``serve`` call)."""
        self.admission = AdmissionController(
            self.res, self.sampler.n_target_type, self.n_classes)
        self.degrade = DegradationController(
            self.res, len(self.sampler.ladder), self.slot_targets)
        self.retry = RetryPolicy(self.res)
        # async sampler refill rides the plan's stage-graph schedule — the
        # host samples step t+1 while the device runs step t's forward
        sched = self.plan.schedule
        self.prefetch = (_SamplerPrefetcher(self.sampler)
                         if sched is not None and sched.prefetch else None)
        self._deadline_expired = 0
        self._failovers = 0
        self._lost_partitions: List[int] = []
        self._status_counts: Dict[str, int] = {}

    def _cache_step(self, ids: np.ndarray, sb) -> None:
        """One serving step's residency traffic: pin the in-flight targets
        (never evicted while their request is being served), run the sampled
        frontier — every type's local->global table — through the live
        caches' deterministic admission policy, then unpin."""
        spec = self.plan.residency
        tgt = self.plan.target
        pin = spec.pin_targets and tgt in self.caches
        if pin:
            self.caches[tgt].pin(ids)
        for t, loc in sb.local.items():
            if t in self.caches:
                self.caches[t].access_many(loc)
        if pin:
            self.caches[tgt].unpin(ids)

    def _forward_batch(self, batch: Dict) -> Dict:
        if self._serve_plan.partition is not None:
            from repro.dist.partition import partition_batch
            return partition_batch(self._serve_plan, batch)
        return batch

    def _predict_next(self, active, q, chunks):
        """Predict the NEXT step's ``(union ids, rung limit)`` by simulating
        this step's completion: each chunk advances its request's cursor,
        exhausted slots refill from the queue in slot order, and the
        chunking re-runs under the *current* degradation level.  Purely
        speculative — deadline expiry, a degradation shift or a failed step
        falsifies it, and ``_SamplerPrefetcher.take`` then discards the
        speculative batch (counted in ``mispredicts``).  Returns ``None``
        when the simulation finds no next step."""
        done = {id(r): start + len(cids) for r, start, cids in chunks}
        qi = list(q)
        qpos = 0
        cursors = []
        for r in active:
            cur = None
            if r is not None:
                d = done.get(id(r), r._done)
                if d < len(r._serve_ids):
                    cur = (r, d)
            if cur is None and qpos < len(qi):
                cur = (qi[qpos], qi[qpos]._done)
                qpos += 1
            cursors.append(cur)
        chunk = self.degrade.chunk()
        rung_limit = self.degrade.rung_limit()
        t_budget = self.sampler.ladder[rung_limit][0]
        parts = []
        n_union = 0
        for cur in cursors:
            if cur is None:
                continue
            if n_union >= t_budget:
                break
            r, d = cur
            take = min(chunk, t_budget - n_union, len(r._serve_ids) - d)
            parts.append(np.asarray(r._serve_ids[d: d + take], np.int64))
            n_union += take
        if not parts:
            return None
        return np.concatenate(parts), rung_limit

    def _maybe_failover(self, step: int) -> None:
        """Injected partition loss -> re-assign the lost partition's
        vertices over the survivors (every subsequent ``partition_batch``
        re-partitions with the shrunk spec; the partitioned head's inverse
        permutation keeps global row order, so outputs stay bit-exact vs a
        never-failed run)."""
        if self.injector is None or self._serve_plan.partition is None:
            return
        lost = self.injector.partition_loss(step)
        if lost is None:
            return
        from repro.dist.partition import surviving_partition_spec
        spec = surviving_partition_spec(self._serve_plan.partition, [lost])
        self._serve_plan = dataclasses.replace(self._serve_plan,
                                               partition=spec)
        self._failovers += 1
        self._lost_partitions.append(int(lost))

    def warmup(self) -> int:
        """Compile every ladder rung on a dummy batch; snapshot the jit
        cache size so ``stats`` can report post-warmup recompiles."""
        for i in range(len(self.sampler.ladder)):
            sb = self.sampler.dummy_batch(i)
            jax.block_until_ready(
                self.fn(self.params, self._forward_batch(sb.batch)))
        self._warm_compiles = self.fn._cache_size()
        return self._warm_compiles

    def serve(self, requests: List[HGNNRequest]) -> List[HGNNRequest]:
        """Run the slot loop until every request reaches a terminal status.

        Never raises for admissible traffic: bad requests are REJECTED at
        admission, deadline-expired ones complete PARTIAL, and persistent
        step errors FAIL only the requests in the affected slots.
        """
        import collections
        import time

        self._fresh_policies()
        adm, deg, retry = self.admission, self.degrade, self.retry
        now = time.perf_counter()
        q: collections.deque = collections.deque()
        for r in requests:
            if adm.admit(r, len(q), now):
                q.append(r)
        active: List[Optional[HGNNRequest]] = [None] * self.slots
        self.step_log = []
        step = 0
        while q or any(r is not None for r in active):
            now = time.perf_counter()
            # deadline expiry: active slots and queued requests complete
            # PARTIAL (rows served so far) without blocking the loop
            active, n_exp = resilience.expire_requests(
                active, now, self.n_classes)
            self._deadline_expired += n_exp
            if q:
                live: collections.deque = collections.deque()
                for r in q:
                    if r._deadline is not None and now >= r._deadline:
                        finalize_request(r, PARTIAL, self.n_classes,
                                         error="deadline expired")
                        self._deadline_expired += 1
                    else:
                        live.append(r)
                q = live
            # refill: degenerate requests completed at admission, so every
            # queued request is servable and takes exactly one free slot
            for s in range(self.slots):
                if active[s] is None and q:
                    active[s] = q.popleft()
                    active[s].status = "ACTIVE"
            # degradation: per-slot chunk + rung clamp (warmed rungs only)
            level_used = deg.level
            chunk = deg.chunk()
            rung_limit = deg.rung_limit()
            t_budget = self.sampler.ladder[rung_limit][0]
            chunks = []  # (request, start_row_in_request, ids)
            n_union = 0
            for r in active:
                if r is None:
                    continue
                if n_union >= t_budget:
                    break  # degraded union budget: remaining slots wait
                take = min(chunk, t_budget - n_union,
                           len(r._serve_ids) - r._done)
                ids = r._serve_ids[r._done: r._done + take]
                chunks.append((r, r._done, np.asarray(ids, np.int64)))
                n_union += take
            if not chunks:  # everything expired this pass
                continue
            self._maybe_failover(step)
            ids = np.concatenate([c[2] for c in chunks])
            t0 = time.perf_counter()
            inj = self.injector
            # prefetch hit: the speculative batch stands in for the sampler
            # call but still runs under the SAME retry policy and fault
            # hook, so injected sampler faults (and their counters) fire
            # identically whether the batch was prefetched or sampled sync
            sb_pre = (self.prefetch.take(ids, rung_limit)
                      if self.prefetch is not None else None)
            sample_call = ((lambda: sb_pre) if sb_pre is not None else
                           (lambda: self.sampler.sample(
                               ids, max_rung=rung_limit)))
            try:
                sb = retry.run(
                    "sampler", sample_call,
                    hook=(lambda a: inj.check("sampler", step, a))
                    if inj else None)
                if self.prefetch is not None:
                    nxt = self._predict_next(active, q, chunks)
                    if nxt is not None:
                        self.prefetch.submit(*nxt)
                out = retry.run(
                    "forward",
                    lambda: np.asarray(
                        self.fn(self.params, self._forward_batch(sb.batch))),
                    hook=(lambda a: inj.check("forward", step, a))
                    if inj else None)
            except StepFailure as e:
                wall = time.perf_counter() - t0
                inj_lat = inj.latency_s(step) if inj else 0.0
                wall_obs = wall + inj_lat
                for r, _start, _cids in chunks:
                    finalize_request(r, FAILED, self.n_classes,
                                     error=str(e))
                for s in range(self.slots):
                    if active[s] is not None and active[s].status == FAILED:
                        active[s] = None
                deg.observe(inj_lat if self.res.slo_signal == "injected"
                            else wall_obs)
                self.step_log.append({
                    "active_slots": len(chunks), "queue_len": len(q),
                    "n_targets": int(len(ids)), "rung_index": -1,
                    "frontier_bytes": 0.0, "truncated_rows": 0,
                    "wall_s": wall, "wall_observed_s": wall_obs,
                    "degrade_level": level_used, "failed": True,
                    "error": str(e),
                })
                step += 1
                continue
            rows = out[sb.target_rows]
            wall = time.perf_counter() - t0
            if self.caches is not None:  # host bookkeeping, untimed
                self._cache_step(ids, sb)
            inj_lat = inj.latency_s(step) if inj else 0.0
            wall_obs = wall + inj_lat
            off = 0
            for r, start, cids in chunks:
                n = len(cids)
                if r._buf is None:
                    r._buf = np.zeros((len(r._serve_ids), rows.shape[1]),
                                      rows.dtype)
                r._buf[start: start + n] = rows[off: off + n]
                r._done = start + n
                off += n
            for s in range(self.slots):
                r = active[s]
                if r is not None and r._done >= len(r._serve_ids):
                    finalize_request(r, OK, self.n_classes)
                    active[s] = None
            deg.observe(inj_lat if self.res.slo_signal == "injected"
                        else wall_obs)
            self.step_log.append({
                "active_slots": len(chunks),
                "queue_len": len(q),
                "n_targets": int(sb.n_targets),
                "rung_index": int(sb.rung_index),
                "frontier_bytes": float(sb.meta["frontier_bytes"]),
                "truncated_rows": int(sb.meta["truncated_rows"]),
                "wall_s": wall,
                "wall_observed_s": wall_obs,
                "degrade_level": level_used,
            })
            self.last_sb = sb
            step += 1
        if self.prefetch is not None:
            self.prefetch.drain()
        for r in requests:
            self._status_counts[r.status] = (
                self._status_counts.get(r.status, 0) + 1)
        return requests

    def stats(self) -> Dict:
        """Deterministic serving counters (walls reported, never gated).

        ``compiles_after_warmup`` is ``None`` until :meth:`warmup` has run —
        there is no warm cache to diff against, so a recompile count would
        be meaningless (previously a silent ``-1`` sentinel).
        """
        rung_hits: Dict[int, int] = {}
        for e in self.step_log:
            if e.get("failed"):
                continue  # failed steps sample no rung
            rung_hits[e["rung_index"]] = rung_hits.get(e["rung_index"], 0) + 1
        compiles = (int(self.fn._cache_size() - self._warm_compiles)
                    if self._warm_compiles is not None else None)
        walls = [e["wall_s"] for e in self.step_log]
        deg, retry, adm = self.degrade, self.retry, self.admission
        inj_counts = dict(self.injector.counters) if self.injector else {}
        out = {
            "steps": len(self.step_log),
            "rung_hits": {int(k): int(v)
                          for k, v in sorted(rung_hits.items())},
            "frontier_bytes": float(
                sum(e["frontier_bytes"] for e in self.step_log)),
            "truncated_rows": int(
                sum(e["truncated_rows"] for e in self.step_log)),
            "compiles_after_warmup": compiles,
            "wall_total_s": float(sum(walls)),
            "wall_mean_ms": float(1e3 * np.mean(walls)) if walls else 0.0,
            "resilience": {
                **{k: int(v) for k, v in adm.counters.items()},
                **{k: int(v) for k, v in retry.counters.items()},
                **{k: int(v) for k, v in deg.counters.items()},
                "retries": int(retry.counters["sampler_retries"]
                               + retry.counters["forward_retries"]),
                "deadline_expired": int(self._deadline_expired),
                "failed_requests": int(
                    self._status_counts.get(FAILED, 0)),
                "partial_requests": int(
                    self._status_counts.get(PARTIAL, 0)),
                "ok_requests": int(self._status_counts.get(OK, 0)),
                "partition_failovers": int(self._failovers),
                "lost_partitions": list(self._lost_partitions),
                "statuses": dict(self._status_counts),
                "injected": inj_counts,
            },
        }
        if self.prefetch is not None:
            out["prefetch"] = {k: int(v)
                               for k, v in self.prefetch.counters.items()}
        if self.caches is not None:
            hits = sum(c.hits for c in self.caches.values())
            misses = sum(c.misses for c in self.caches.values())
            out["residency"] = {
                "per_type": {t: dict(c.counters)
                             for t, c in sorted(self.caches.items())},
                "hits": int(hits),
                "misses": int(misses),
                "rows": int(hits + misses),
                "hit_rate": float(hits / max(hits + misses, 1)),
                "evictions": int(sum(c.evictions
                                     for c in self.caches.values())),
                "cache_rows": int(sum(c.capacity
                                      for c in self.caches.values())),
            }
        return out


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [T] int32
    max_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 8,
                 max_len: int = 512, rng_seed: int = 0, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.key(rng_seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: tf.lm_decode_step(p, cfg, t, c, pos))

    def _sample(self, logits: jax.Array, temps: Optional[jax.Array]) -> jax.Array:
        """Per-slot sampling: each request in the wave keeps its own
        temperature (greedy where <= 0, categorical otherwise).  ``temps``
        is the device array built ONCE per wave by ``_run_wave`` — None
        means an all-greedy wave, so the per-token loop never re-uploads or
        re-reduces wave-constant facts."""
        greedy = jnp.argmax(logits, axis=-1)
        if temps is None:
            return greedy
        self.key, sub = jax.random.split(self.key)
        sampled = jax.random.categorical(
            sub, logits / jnp.maximum(temps, 1e-6)[:, None], axis=-1)
        return jnp.where(temps > 0.0, sampled, greedy)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Simple batched generation: pad prompts to a common length, prefill
        once, then decode lock-step (same-length prompts per wave)."""
        out: List[Request] = []
        for wave_start in range(0, len(requests), self.slots):
            wave = requests[wave_start: wave_start + self.slots]
            out.extend(self._run_wave(wave))
        return out

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(wave)
        t0 = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, t0), np.int32)
        for i, r in enumerate(wave):
            toks[i, t0 - len(r.prompt):] = r.prompt  # left-pad
        logits, pf_caches = tf.lm_prefill(self.params, cfg, jnp.asarray(toks))
        caches = tf.graft_prefill_caches(
            cfg, tf.init_kv_caches(cfg, b, self.max_len), pf_caches, t0)
        max_new = max(r.max_tokens for r in wave)
        temps_host = np.array([r.temperature for r in wave], np.float32)
        temps = (jnp.asarray(temps_host) if (temps_host > 0).any() else None)
        cur = self._sample(logits[:, 0], temps)
        outs = [[int(cur[i])] for i in range(b)]
        done = np.zeros(b, bool)
        for step in range(1, max_new):
            pos = jnp.int32(t0 + step - 1)
            logits, caches = self._decode(self.params, cur[:, None], caches, pos)
            cur = self._sample(logits[:, 0], temps)
            for i in range(b):
                if done[i] or step >= wave[i].max_tokens:
                    done[i] = True
                    continue
                t = int(cur[i])
                outs[i].append(t)
                if t == self.eos_id:
                    done[i] = True
            if done.all():
                break
        for r, o in zip(wave, outs):
            r.out_tokens = o[: r.max_tokens]
        return wave
