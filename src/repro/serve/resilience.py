"""Serving resilience policies for the HGNN request path.

The paper's core observation — HGNN stage behavior is *predictable and
measurable* — is what makes principled degradation possible on the serve
path: the per-step walls and SAMPLE counters the engine already records are
the load signals, and the sampler's fixed shape-bucket ladder is a
ready-made degradation axis (serving a smaller rung costs frontier
coverage, never a recompile).  This module holds the policy objects
``HGNNServeEngine.serve`` threads through its slot loop:

* :class:`ResilienceConfig` — one knob surface: admission bounds,
  per-request deadline default, per-step wall budget, SLO target, retry
  budget/backoff, degradation patience.
* :class:`AdmissionController` — validates a request before it can touch
  the union batch (integer dtype, id range, size cap), dedups duplicate
  target ids (served once, fanned back out on completion), completes
  zero-target requests immediately, and sheds on a bounded queue.  The
  result is a structured per-request status instead of a mid-batch crash.
* :class:`DegradationController` — a pressure level driven by SLO/step
  budget breaches.  Level ``l`` shrinks the per-slot target chunk
  (``slot_targets >> l``) and clamps the sampler's rung choice to
  ``n_rungs - 1 - l`` — both moves stay strictly inside the warmed ladder,
  so ``compiles_after_warmup`` stays 0 while pressure lasts, and the level
  steps back down after ``recover_patience`` healthy steps.
* :class:`RetryPolicy` — bounded retry-with-backoff around the sampler
  call and the jitted forward; persistent errors surface as
  :class:`StepFailure` and fail only the affected slots' requests.

Status lifecycle (terminal states are what ``serve`` returns)::

    NEW --admit--> QUEUED --slot--> ACTIVE --all rows served--> OK
      |               |                |--deadline expired----> PARTIAL
      |               |--deadline----> PARTIAL (0 rows)
      |               '--(queue full)  REJECTED [shed]
      '--(bad dtype / id range / size) REJECTED
                      ACTIVE --persistent step error----------> FAILED
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# Terminal request statuses (see the lifecycle diagram above).
OK = "OK"
PARTIAL = "PARTIAL"
REJECTED = "REJECTED"
FAILED = "FAILED"
TERMINAL = (OK, PARTIAL, REJECTED, FAILED)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the serve path's resilience policies.

    Defaults are deliberately inert where behavior could change for
    existing callers: no deadline, no SLO, unbounded queue, no size cap.
    Retries default on (2) because without an injector the only effect is
    surviving a transient host error that previously crashed the loop.
    """
    max_queue: Optional[int] = None       # admission bound; None = unbounded
    max_request_targets: Optional[int] = None  # per-request size cap
    deadline_ms: Optional[float] = None   # default per-request deadline
    step_budget_ms: Optional[float] = None  # per-step wall budget (pressure)
    slo_ms: Optional[float] = None        # SLO target driving degradation
    max_retries: int = 2                  # attempts = max_retries + 1
    backoff_base_s: float = 0.0           # sleep base * 2**attempt between
    degrade_patience: int = 2             # breaches before stepping level up
    recover_patience: int = 3             # healthy steps before stepping down
    # Which wall feeds the SLO comparison: "observed" (real step wall +
    # injected latency — production semantics) or "injected" (the
    # FaultInjector's latency schedule only — replay-deterministic, so the
    # chaos bench/CI can gate exact degrade/recover counters on any host).
    slo_signal: str = "observed"


class StepFailure(RuntimeError):
    """A serve step exhausted its retry budget (``stage`` names which call)."""

    def __init__(self, stage: str, cause: Exception):
        super().__init__(f"{stage} failed after retries: {cause}")
        self.stage = stage
        self.cause = cause


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class AdmissionController:
    """Validate/normalize requests before they can reach the union batch.

    ``admit`` mutates the request in place (statuses, the deduped serve-id
    view) and returns True only for requests that should enter the queue;
    everything else reaches a terminal status here.  Counters are the
    deterministic admission half of ``HGNNServeEngine.stats()``.
    """

    def __init__(self, res: ResilienceConfig, n_target_type: int,
                 n_classes: int):
        self.res = res
        self.n_target_type = n_target_type
        self.n_classes = n_classes
        self.counters: Dict[str, int] = {
            "admitted": 0, "rejected": 0, "shed": 0, "deduped_rows": 0,
            "degenerate_completed": 0,
        }

    def _reject(self, r, reason: str, shed: bool = False) -> bool:
        r.status = REJECTED
        r.error = reason
        r.logits = np.zeros((0, self.n_classes), np.float32)
        r.served = np.zeros(0, np.int64)
        self.counters["rejected"] += 1
        if shed:
            self.counters["shed"] += 1
        return False

    def admit(self, r, queue_len: int, now: float) -> bool:
        res = self.res
        targets = np.asarray(r.targets)
        if targets.size and not np.issubdtype(targets.dtype, np.integer):
            return self._reject(r, f"non-integer target dtype "
                                   f"{targets.dtype}")
        targets = targets.astype(np.int64).reshape(-1)
        if targets.size and (targets.min() < 0
                             or targets.max() >= self.n_target_type):
            return self._reject(
                r, f"target ids out of range [0, {self.n_target_type})")
        if (res.max_request_targets is not None
                and len(targets) > res.max_request_targets):
            return self._reject(
                r, f"{len(targets)} targets exceed the "
                   f"{res.max_request_targets}-target request cap")
        if len(targets) == 0:
            # degenerate: complete at admission so it never occupies a
            # refill iteration or a slot (the class dim is n_classes so
            # downstream concatenation over requests stays well-formed)
            r.status = OK
            r.logits = np.zeros((0, self.n_classes), np.float32)
            r.served = np.zeros(0, np.int64)
            self.counters["degenerate_completed"] += 1
            return False
        if res.max_queue is not None and queue_len >= res.max_queue:
            return self._reject(r, f"queue full ({res.max_queue})", shed=True)
        # dedup: duplicate target ids are served once and fanned back out
        # to every duplicate row at completion
        uniq, inv = np.unique(targets, return_inverse=True)
        self.counters["deduped_rows"] += int(len(targets) - len(uniq))
        r._serve_ids = uniq
        r._inv = inv.astype(np.int64)
        r._buf = None
        r._done = 0
        deadline_ms = (r.deadline_ms if r.deadline_ms is not None
                       else res.deadline_ms)
        r._deadline = (now + deadline_ms / 1e3
                       if deadline_ms is not None else None)
        r.status = "QUEUED"
        self.counters["admitted"] += 1
        return True


# ---------------------------------------------------------------------------
# graceful degradation over the warmed ladder
# ---------------------------------------------------------------------------


class DegradationController:
    """SLO-pressure level mapping to (chunk, rung-limit) degradation.

    Both degradation axes stay inside the shape space ``warmup()`` already
    compiled: shrinking the per-slot chunk only changes how many target
    rows are real in a rung's padded batch, and clamping the rung choice
    picks a *smaller warmed rung* (costing frontier truncation, which the
    sampler counts).  Nothing here can introduce a new shape, so
    ``compiles_after_warmup`` stays 0 under any pressure trajectory.
    """

    def __init__(self, res: ResilienceConfig, n_rungs: int,
                 slot_targets: int):
        self.res = res
        self.n_rungs = n_rungs
        self.slot_targets = slot_targets
        # level exhausts both axes: chunk -> 1 and rung limit -> 0
        self.max_level = (n_rungs - 1) + max(
            0, int(np.ceil(np.log2(max(slot_targets, 1)))))
        self.level = 0
        self._breach_streak = 0
        self._ok_streak = 0
        self.counters: Dict[str, int] = {
            "degrade_steps": 0, "degrade_transitions": 0,
            "recover_transitions": 0, "max_degrade_level": 0,
            "over_budget_steps": 0,
        }

    @property
    def active(self) -> bool:
        return (self.res.slo_ms is not None
                or self.res.step_budget_ms is not None)

    def chunk(self) -> int:
        """Per-slot target chunk at the current pressure level."""
        return max(1, self.slot_targets >> self.level)

    def rung_limit(self) -> int:
        """Largest ladder rung index the sampler may pick right now."""
        return max(0, self.n_rungs - 1 - self.level)

    def observe(self, wall_s: float) -> int:
        """Feed one step's observed wall; returns the (new) level."""
        res = self.res
        if self.level > 0:
            self.counters["degrade_steps"] += 1
        if not self.active:
            return self.level
        over_budget = (res.step_budget_ms is not None
                       and wall_s * 1e3 > res.step_budget_ms)
        if over_budget:
            self.counters["over_budget_steps"] += 1
        breach = over_budget or (res.slo_ms is not None
                                 and wall_s * 1e3 > res.slo_ms)
        if breach:
            self._breach_streak += 1
            self._ok_streak = 0
            if (self._breach_streak >= res.degrade_patience
                    and self.level < self.max_level):
                self.level += 1
                self._breach_streak = 0
                self.counters["degrade_transitions"] += 1
                self.counters["max_degrade_level"] = max(
                    self.counters["max_degrade_level"], self.level)
        else:
            self._ok_streak += 1
            self._breach_streak = 0
            if self._ok_streak >= res.recover_patience and self.level > 0:
                self.level -= 1
                self._ok_streak = 0
                self.counters["recover_transitions"] += 1
        return self.level


# ---------------------------------------------------------------------------
# bounded retry-with-backoff
# ---------------------------------------------------------------------------


class RetryPolicy:
    """Retry a callable up to ``max_retries`` extra attempts with
    exponential backoff; raise :class:`StepFailure` on exhaustion.

    ``hook(attempt)`` runs before each attempt — the engine points it at
    ``FaultInjector.check`` so injected and real exceptions share the
    exact same recovery path.
    """

    def __init__(self, res: ResilienceConfig):
        self.res = res
        self.counters: Dict[str, int] = {
            "sampler_retries": 0, "forward_retries": 0, "failed_steps": 0,
        }

    def run(self, stage: str, call: Callable,
            hook: Optional[Callable[[int], None]] = None):
        last: Optional[Exception] = None
        for attempt in range(self.res.max_retries + 1):
            try:
                if hook is not None:
                    hook(attempt)
                return call()
            except Exception as e:  # noqa: BLE001 — every error is retryable
                last = e
                if attempt < self.res.max_retries:
                    self.counters[f"{stage}_retries"] += 1
                    if self.res.backoff_base_s > 0:
                        time.sleep(self.res.backoff_base_s * (2 ** attempt))
        self.counters["failed_steps"] += 1
        raise StepFailure(stage, last)


# ---------------------------------------------------------------------------
# request finalization (shared by deadline / failure / completion paths)
# ---------------------------------------------------------------------------


def finalize_request(r, status: str, n_classes: int,
                     error: Optional[str] = None) -> None:
    """Move an admitted request to a terminal status, expanding the deduped
    working buffer back to request order.

    ``OK``: every unique id served — ``logits`` has one row per original
    target (duplicates fanned out).  ``PARTIAL``/``FAILED``: only rows
    whose unique id was served survive, compacted in request order, with
    ``served`` naming the target ids those rows answer.
    """
    if r._serve_ids is None:  # rejected/degenerate: already finalized
        r.status = status
        if error is not None:
            r.error = error
        return
    done = int(r._done)
    buf = (r._buf if r._buf is not None
           else np.zeros((len(r._serve_ids), n_classes), np.float32))
    if done >= len(r._serve_ids) and status == OK:
        r.logits = buf[r._inv]
        r.served = np.asarray(r.targets).reshape(-1).copy()
    else:
        mask = r._inv < done
        r.logits = buf[r._inv[mask]]
        r.served = np.asarray(r.targets).reshape(-1)[mask]
    r.status = status
    if error is not None:
        r.error = error


def expire_requests(requests: List, now: float, n_classes: int,
                    ) -> Tuple[List, int]:
    """Split ``requests`` into (still-live, expired-count); expired ones
    finalize as PARTIAL with the rows served so far."""
    live: List = []
    expired = 0
    for r in requests:
        if r is None:
            live.append(r)
            continue
        if r._deadline is not None and now >= r._deadline:
            finalize_request(r, PARTIAL, n_classes, error="deadline expired")
            expired += 1
            live.append(None)
        else:
            live.append(r)
    return live, expired
