from repro.configs.base import (  # noqa: F401
    HGNNConfig,
    LONG_CONTEXT_ARCHS,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    long_context_supported,
)
from repro.configs.registry import get_config, get_reduced, list_archs  # noqa: F401
