"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns the exact abstract inputs the step
function takes (no device allocation); ``input_shardings`` returns the
matching NamedSharding pytree.  Both follow the kind:

  train   -> {tokens, labels [, extra_embeds | frames]}
  prefill -> {tokens [, extra_embeds | frames]}
  decode  -> {token, pos, caches}   (KV/SSM caches are step INPUTS: serving)
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import BATCH, MODEL, resolve_spec
from repro.nn.ssm import MambaCache
from repro.nn.transformer import init_kv_caches, layer_runs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind == "train":
        out: Dict[str, Any] = {
            "tokens": _sds((b, s), "int32"),
            "labels": _sds((b, s), "int32"),
        }
        if cfg.family == "vlm":
            out["extra_embeds"] = _sds((b, cfg.n_frontend_embeds, cfg.d_model), dt)
        if cfg.family == "encdec":
            out["frames"] = _sds((b, s, cfg.d_model), dt)
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((b, s), "int32")}
        if cfg.family == "vlm":
            out["extra_embeds"] = _sds((b, cfg.n_frontend_embeds, cfg.d_model), dt)
        if cfg.family == "encdec":
            out["frames"] = _sds((b, s, cfg.d_model), dt)
        return out
    # decode: one new token against an s-long cache
    if cfg.family == "encdec":
        from repro.nn.encdec import init_encdec_caches

        caches = jax.eval_shape(lambda: init_encdec_caches(cfg, b, s, s))
    else:
        caches = jax.eval_shape(lambda: init_kv_caches(cfg, b, s))
    return {
        "token": _sds((b, 1), "int32"),
        "pos": _sds((), "int32"),
        "caches": caches,
    }


def _ns(mesh, shape, axes):
    return NamedSharding(mesh, resolve_spec(shape, axes, mesh))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, caches) -> Any:
    """Shardings matching the cache pytree (list per run | encdec dict)."""
    seq = MODEL if cfg.decode_kv_shard_seq else None
    kvh = None if cfg.decode_kv_shard_seq else MODEL

    if isinstance(caches, dict):  # encdec: stacked [L,B,S,KVH,Dh] buffers
        return {
            k: _ns(mesh, v.shape, (None, BATCH, seq, kvh, None))
            for k, v in caches.items()
        }
    out = []
    for (kind, count), c in zip(layer_runs(cfg), caches):
        if isinstance(c, MambaCache):
            out.append(MambaCache(
                state=_ns(mesh, c.state.shape, (None, BATCH, MODEL, None, None)),
                conv_x=_ns(mesh, c.conv_x.shape, (None, BATCH, None, MODEL)),
                conv_B=_ns(mesh, c.conv_B.shape, (None, BATCH, None, None)),
                conv_C=_ns(mesh, c.conv_C.shape, (None, BATCH, None, None)),
            ))
        else:
            out.append({
                k: _ns(mesh, c[k].shape, (None, BATCH, seq, kvh, None))
                for k in ("k", "v")
            })
    return out


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    specs = input_specs(cfg, shape)
    out: Dict[str, Any] = {}
    for name, sds in specs.items():
        if name in ("tokens", "labels", "token"):
            out[name] = _ns(mesh, sds.shape, (BATCH, None))
        elif name in ("extra_embeds", "frames"):
            out[name] = _ns(mesh, sds.shape, (BATCH, None, None))
        elif name == "pos":
            out[name] = NamedSharding(mesh, P())
        elif name == "caches":
            out[name] = cache_shardings(cfg, mesh, sds)
        else:
            raise KeyError(name)
    return out
