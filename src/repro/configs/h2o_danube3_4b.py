"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA [arXiv:2401.16818; unverified].

Sliding-window attention (4096) makes decode sub-quadratic with a
ring-buffer KV cache -> long_500k runs for this arch (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
        d_ff=10240, vocab=32000, sliding_window=4096,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=32,
        dtype="float32", param_dtype="float32", attn_chunk=64,
    )
