"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2 [arXiv:2404.16821; unverified].

The vision frontend is a STUB per the brief: input_specs provides
precomputed patch embeddings [B, 256, d_model] prepended to the tokens.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab=128256,
        frontend="vision", n_frontend_embeds=256,
        remat="full", n_microbatches=4,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, n_frontend_embeds=8,
        dtype="float32", param_dtype="float32", attn_chunk=64,
    )
