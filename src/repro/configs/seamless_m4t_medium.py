"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Encoder-decoder: 12 encoder + 12 decoder layers. The audio frontend is a
STUB per the brief: input_specs provides precomputed frame embeddings
[B, S_src, d_model] for the encoder.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=12, enc_layers=12, dec_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=256206, frontend="audio",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, enc_layers=2, dec_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", param_dtype="float32",
        attn_chunk=64,
    )
