"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ModelConfig

ARCHS = {
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-2.7b": "mamba2_2p7b",
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-1.2b": "zamba2_1p2b",
}


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def list_archs() -> List[str]:
    return sorted(ARCHS)
