"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38 Mamba2 layers with ONE shared attention+MLP block (single param set)
applied every 19 layers (2 applications), matching the weight-sharing idea.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=32000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=256),
        shared_attn_period=19, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, shared_attn_period=2,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
        dtype="float32", param_dtype="float32", attn_chunk=64,
    )
