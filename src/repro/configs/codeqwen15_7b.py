"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=13440 vocab=92416 — qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=13440, vocab=92416,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", param_dtype="float32",
        attn_chunk=64,
    )
