"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

15 heads do not divide the 16-way 'model' axis: the baseline auto-replicates
the head dim (dist/sharding.py guard); pad_heads_to_mesh is the optimized
variant (§Perf).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab=49152, tie_embeddings=True,
        # §Perf cell A optimum: padded heads (15->16, 5->16) + 1k attn chunks
        pad_heads_to_mesh=True, attn_chunk=1024,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=128, vocab=512, dtype="float32", param_dtype="float32",
        attn_chunk=64,
    )
