"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=49152,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", param_dtype="float32",
        attn_chunk=64,
    )
