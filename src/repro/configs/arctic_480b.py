"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=4864, vocab=32000,
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual_ff=4864, capacity_factor=1.25),
        # 480B params: factored optimizer state so train fits the pod
        optimizer="adafactor", remat="full", n_microbatches=4,
        # §Perf cell C optimum: 56->64 q heads / 8->16 kv heads (zero-padded)
        pad_heads_to_mesh=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      dense_residual_ff=96, capacity_factor=2.0),
        dtype="float32", param_dtype="float32", attn_chunk=64,
    )
