"""Config dataclasses for the repro framework.

Two config families:
  * ``ModelConfig`` — the 10 assigned LM architectures (+ reduced smoke
    variants).  One module per arch under ``repro.configs``; each exposes
    ``config()`` (full, dry-run only) and ``reduced()`` (CPU smoke).
  * ``HGNNConfig`` — the paper's HGNN workloads (RGCN / HAN / MAGNN / GCN on
    IMDB / ACM / DBLP / Reddit-like).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# LM architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (Switch/GShard-style einsum dispatch)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    # Arctic runs a dense FFN *in parallel* with the MoE FFN ("dense residual").
    dense_residual_ff: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD — state-space duality) block config."""

    d_state: int
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256  # SSD chunk length (intra-chunk quadratic, inter-chunk scan)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Sliding-window attention width; 0 = full causal attention.
    sliding_window: int = 0
    # Encoder-decoder (seamless-m4t): n_layers applies to each side.
    enc_layers: int = 0
    dec_layers: int = 0
    # Modality frontend stub: number of precomputed embeddings prepended.
    frontend: Optional[str] = None  # vision | audio
    n_frontend_embeds: int = 0
    # zamba2: one shared attention block applied every `shared_attn_period`
    # Mamba2 layers (weights shared across applications).
    shared_attn_period: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # Optimizer / memory knobs (needed so the biggest archs fit the pod).
    optimizer: str = "adamw"  # adamw | adafactor
    opt_state_dtype: str = "float32"
    # 'full' is the safe default: 'dots' saves every no-batch-dim matmul
    # output across the layer scan (8 GiB/step f32 on smollm alone) — see
    # EXPERIMENTS.md §Perf for the measured comparison.
    remat: str = "full"  # none | dots | full
    # q/kv-chunk length for the online-softmax (flash-style) attention path.
    # 512 keeps the fp32 score tile (B_local x H_local x cq x ck) HBM-friendly
    # even when heads cannot shard (see EXPERIMENTS.md §Perf smollm study).
    attn_chunk: int = 512
    # Pallas kernels are TPU-only; dry-run path keeps this False (CPU backend
    # cannot compile TPU custom calls). Tests exercise kernels in interpret mode.
    use_pallas: bool = False
    # --- beyond-paper perf knobs (hillclimb; see EXPERIMENTS.md §Perf) ---
    # Pad attention heads up to a multiple of the 'model' axis so GSPMD does
    # not fall back to uneven/halo sharding (arctic: 56 -> 64).
    pad_heads_to_mesh: bool = False
    # Shard the decode KV cache's sequence dim over 'model' (flash-decode).
    decode_kv_shard_seq: bool = True
    # FSDP (ZeRO-3) over the 'data' axis in addition to TP over 'model'.
    # Required for the 76B/480B archs' optimizer state to fit a pod.
    fsdp: bool = True
    # FSDP also on expert weights (arctic: needed; phi3.5: EP alone fits and
    # skipping saves per-layer expert all-gathers — §Perf H-B2).
    fsdp_experts: bool = True
    # Megatron-style sequence parallelism: residual stream sharded over
    # 'model' at layer boundaries, so the per-layer activations saved by the
    # remat'd layer scan divide by the model axis (internvl2 train: 91 GB ->
    # 5.7 GB of carries per device).
    seq_shard_activations: bool = True
    # Gradient-accumulation microbatches per train step. Divides the
    # per-microbatch activation transients (the full-seq fp32 tensors at TP
    # matmul boundaries) — how the 76B/480B train cells fit 16 GB HBM.
    n_microbatches: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs for which long_500k is runnable (sub-quadratic decode path);
# all other archs are pure full-attention -> skip recorded in DESIGN.md §4.
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "zamba2-1.2b", "h2o-danube-3-4b")


def long_context_supported(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0


# ---------------------------------------------------------------------------
# HGNN configs (the paper's workloads)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HGNNConfig:
    model: str = "han"  # rgcn | han | magnn | gcn
    dataset: str = "imdb"  # imdb | acm | dblp | reddit
    hidden: int = 64
    n_classes: int = 8
    n_heads: int = 8  # GAT heads in Neighbor Aggregation
    attn_hidden: int = 128  # semantic-attention hidden dim
    max_degree: int = 64  # padded-neighbor cap (TPU-friendly dense layout)
    max_instances: int = 16  # MAGNN instances sampled per target node
    # Optimized (beyond-paper / guideline) execution path:
    #   stacked subgraphs (inter-subgraph parallelism), concat-free SA,
    #   optionally the fused GAT-NA / FP+NA kernels.
    fused: bool = False
    use_pallas: bool = False
    # Degree-bucketed padded NA layout: >1 bins rows into that many K-caps
    # (core/metapath.py bucket_padded) instead of one K=max_degree pad;
    # 0/1 keeps the single stacked [P, N, K] layout. Fused path only
    # (HAN's stacked metapaths and RGCN's per-relation tables).
    degree_buckets: int = 0
    # Fused NA→SA epilogue (inter-stage data reuse): the semantic-score
    # pass-1 partial accumulates inside the NA kernel while each z tile is
    # still in VMEM, saving one full [P, N, D] HBM read. Stacked layout only.
    fuse_na_sa: bool = False
    # Graph-partitioned multi-host execution (repro.dist.partition): >= 1
    # splits the vertex/feature tables into that many edge-cut partitions —
    # FP/NA run per-partition on local shards with an explicit halo feature
    # exchange between them. 0 keeps the single-table execution. Needs the
    # stacked (HAN) / padded (RGCN) / instances (MAGNN) NA layouts.
    partitions: int = 0
    # Stacked FP->NA->SA layers (real deployments run 2-3; the training
    # characterization, arXiv:2407.11790, measures the stage mix shifting
    # with depth). 1 = the paper's single pass, bit-exact with the
    # pre-multi-layer path. The graph-side index tables are layer-invariant
    # (built once in prepare()); each extra layer adds its own FP/NA/SA
    # params and, when partitioned, re-exchanges the updated halo features.
    layers: int = 1
    # Request-path serving (repro.serve.sampler): >= 1 declares the plan
    # sampled-minibatch capable with that per-hop neighbor fan-out cap.
    # 0 keeps the full-graph execution (prepare() builds the whole graph).
    fanout: int = 0
    # Shape-bucket ladder for sampled batches: (t_cap, f_cap) rungs the
    # sampler pads every minibatch to, so the jitted executor compiles one
    # forward per rung at warmup and never recompiles while serving.
    # () = a small automatic ladder derived from fanout/layers.
    sample_ladder: Tuple[Tuple[int, int], ...] = ()
    # Hot-feature residency (repro.core.residency): >= 1 keeps that many
    # hot rows per node type resident in a degree-ordered feature cache.
    # Every gather path consults it — NA neighbor tables remap into the
    # cache-extended source pool, the partitioned arm's hot halo rows skip
    # the exchange, and the serving engine's per-step sampled frontier
    # runs against a live pinned cache. 0 = no cache (every gather re-reads
    # HBM). Bit-exact by construction: cache rows are bitwise row copies.
    cache_rows: int = 0
    # Async stage-graph schedule (core/plan.py ScheduleSpec): >= 1 runs the
    # executor's dependency DAG with that many stages in flight — the halo
    # exchange overlaps NA over owned rows, per-metapath NA stages dispatch
    # concurrently (merge at SA), and serving prefetches the next slot
    # batch while the device computes. 1 is the serial-degenerate schedule
    # (every stage blocked — the parity baseline); 0 keeps the strict
    # serial stage loop with no schedule at all. Bit-exact either way:
    # overlap changes when stages run, never what they compute.
    overlap: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.layers < 1:
            raise ValueError(
                f"HGNNConfig.layers must be >= 1 (got {self.layers})")

    def replace(self, **kw) -> "HGNNConfig":
        return dataclasses.replace(self, **kw)
