"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=80, n_kv_heads=80,  # heads = d_inner/64
        d_ff=0, vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
        dtype="float32", param_dtype="float32", attn_chunk=64,
    )
