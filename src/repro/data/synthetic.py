"""Synthetic heterogeneous graphs matching the paper's Table 2 statistics.

No network access is available offline, so IMDB / ACM / DBLP are generated
randomly with the *exact* node counts, raw feature dimensions and relation
edge counts of Table 2, with power-law-ish degree distributions (real HGs are
heavy-tailed; degree skew is what drives the paper's "irregular memory access"
observation, so we preserve it).

Reddit (used in the paper only for the HAN-vs-GCN comparison, Fig. 5) is
generated at a configurable scale of the real 233k-node / 115M-edge graph —
the default 0.1 scale keeps CPU benchmark time sane while preserving the
average degree (~492).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np
import scipy.sparse as sp

from repro.core.hgraph import HeteroGraph, Relation


def _powerlaw_weights(n: int, rng: np.random.Generator, alpha: float = 1.3) -> np.ndarray:
    w = rng.pareto(alpha, size=n) + 1.0
    return w / w.sum()


def _random_bipartite(
    n_src: int, n_dst: int, n_edges: int, rng: np.random.Generator
) -> sp.csr_matrix:
    """Random bipartite edges with power-law dst popularity, deduplicated."""
    p_dst = _powerlaw_weights(n_dst, rng)
    # oversample then dedup to land close to the requested count
    m = int(n_edges * 1.3) + 16
    src = rng.integers(0, n_src, size=m)
    dst = rng.choice(n_dst, size=m, p=p_dst)
    key = src.astype(np.int64) * n_dst + dst
    _, idx = np.unique(key, return_index=True)
    idx = idx[:n_edges]
    a = sp.csr_matrix(
        (np.ones(len(idx), np.float32), (src[idx], dst[idx])),
        shape=(n_src, n_dst),
    )
    return a


def _features(counts: Dict[str, int], dims: Dict[str, int], rng) -> Dict[str, np.ndarray]:
    return {
        t: rng.standard_normal((counts[t], dims[t]), dtype=np.float32) * 0.1
        for t in counts
    }


def make_imdb(seed: int = 0) -> HeteroGraph:
    """IMDB: movie 4278 / director 2081 / actor 5257; M-D 4278, M-A 12828."""
    rng = np.random.default_rng(seed)
    counts = {"M": 4278, "D": 2081, "A": 5257}
    dims = {"M": 3066, "D": 2081, "A": 5257}
    md = _random_bipartite(counts["M"], counts["D"], 4278, rng)
    ma = _random_bipartite(counts["M"], counts["A"], 12828, rng)
    relations: Dict[Relation, sp.csr_matrix] = {
        ("M", "md", "D"): md,
        ("D", "dm", "M"): md.T.tocsr(),
        ("M", "ma", "A"): ma,
        ("A", "am", "M"): ma.T.tocsr(),
    }
    g = HeteroGraph(counts, _features(counts, dims, rng), relations, name="imdb")
    g.validate()
    return g


def make_acm(seed: int = 0) -> HeteroGraph:
    """ACM: author 5912 / paper 3025 / subject 57; P-A 9936, P-S 3025."""
    rng = np.random.default_rng(seed + 1)
    counts = {"A": 5912, "P": 3025, "S": 57}
    dims = {"A": 1902, "P": 1902, "S": 1902}
    pa = _random_bipartite(counts["P"], counts["A"], 9936, rng)
    ps = _random_bipartite(counts["P"], counts["S"], 3025, rng)
    relations: Dict[Relation, sp.csr_matrix] = {
        ("P", "pa", "A"): pa,
        ("A", "ap", "P"): pa.T.tocsr(),
        ("P", "ps", "S"): ps,
        ("S", "sp", "P"): ps.T.tocsr(),
    }
    g = HeteroGraph(counts, _features(counts, dims, rng), relations, name="acm")
    g.validate()
    return g


def make_dblp(seed: int = 0) -> HeteroGraph:
    """DBLP: author 4057 / paper 14328 / term 7723 / venue 20."""
    rng = np.random.default_rng(seed + 2)
    counts = {"A": 4057, "P": 14328, "T": 7723, "V": 20}
    dims = {"A": 334, "P": 14328, "T": 7723, "V": 20}
    pa = _random_bipartite(counts["P"], counts["A"], 19645, rng)
    pt = _random_bipartite(counts["P"], counts["T"], 85810, rng)
    pv = _random_bipartite(counts["P"], counts["V"], 14328, rng)
    relations: Dict[Relation, sp.csr_matrix] = {
        ("P", "pa", "A"): pa,
        ("A", "ap", "P"): pa.T.tocsr(),
        ("P", "pt", "T"): pt,
        ("T", "tp", "P"): pt.T.tocsr(),
        ("P", "pv", "V"): pv,
        ("V", "vp", "P"): pv.T.tocsr(),
    }
    g = HeteroGraph(counts, _features(counts, dims, rng), relations, name="dblp")
    g.validate()
    return g


def make_reddit_like(scale: float = 0.1, seed: int = 0) -> HeteroGraph:
    """Homogeneous Reddit-like graph (232,965 nodes / 114.6M edges / 602 feats)
    at ``scale``, preserving the ~492 average degree. Stored as a one-type HG
    so the same machinery runs GCN (paper's comparison baseline) and HAN.
    """
    rng = np.random.default_rng(seed + 3)
    n = max(64, int(232_965 * scale))
    avg_deg = 114_615_892 / 232_965
    n_edges = int(n * avg_deg * scale) if scale < 1.0 else 114_615_892
    n_edges = max(n * 4, min(n_edges, 4_000_000))  # CPU-tractable cap
    a = _random_bipartite(n, n, n_edges, rng)
    a = ((a + a.T) > 0).astype(np.float32).tocsr()  # symmetrize
    counts = {"N": n}
    feats = {"N": rng.standard_normal((n, 602), dtype=np.float32) * 0.1}
    g = HeteroGraph(counts, feats, {("N", "nn", "N"): a}, name="reddit")
    g.validate()
    return g


# Target node type + the standard HAN/MAGNN metapath sets per dataset.
DATASET_TARGET = {"imdb": "M", "acm": "P", "dblp": "A", "reddit": "N"}
DATASET_METAPATHS: Dict[str, List[List[str]]] = {
    "imdb": [["M", "D", "M"], ["M", "A", "M"]],
    "acm": [["P", "A", "P"], ["P", "S", "P"]],
    "dblp": [["A", "P", "A"], ["A", "P", "T", "P", "A"], ["A", "P", "V", "P", "A"]],
    "reddit": [["N", "N"]],
}


def make_dataset(name: str, seed: int = 0, scale: float = 0.1) -> HeteroGraph:
    if name == "imdb":
        return make_imdb(seed)
    if name == "acm":
        return make_acm(seed)
    if name == "dblp":
        return make_dblp(seed)
    if name == "reddit":
        return make_reddit_like(scale=scale, seed=seed)
    raise ValueError(f"unknown dataset {name}")
