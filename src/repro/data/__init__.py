from repro.data.synthetic import (  # noqa: F401
    make_acm,
    make_dblp,
    make_dataset,
    make_imdb,
    make_reddit_like,
    DATASET_METAPATHS,
    DATASET_TARGET,
)
