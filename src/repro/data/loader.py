"""Host-sharded synthetic token pipeline with background prefetch.

Every batch is a pure function of (step, host shard) — the elasticity
contract (train/elastic.py): any restarted host regenerates exactly the
slice it owes, with no central dispatcher.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                host_id: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Deterministic batch for (step, host)."""
    b = shape.global_batch // n_hosts
    rng = np.random.default_rng(hash((step, host_id)) % (2 ** 31))
    tokens = rng.integers(0, cfg.vocab, (b, shape.seq_len), dtype=np.int32)
    out = {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        out["extra_embeds"] = rng.standard_normal(
            (b, cfg.n_frontend_embeds, cfg.d_model)).astype(np.float32) * 0.1
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (b, shape.seq_len, cfg.d_model)).astype(np.float32) * 0.1
    return out


class PrefetchLoader:
    """Background-thread prefetch of synth batches (depth-2 pipeline)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1, depth: int = 2):
        self.cfg, self.shape = cfg, shape
        self.host_id, self.n_hosts = host_id, n_hosts
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.shape, step, self.host_id, self.n_hosts)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._t.join(timeout=2.0)
