"""Pallas TPU kernels: concat-free Semantic Aggregation.

The paper shows SA paying 17.5% of its time in DR-Type concat plus
memory-bound EW kernels (uEleWise 82.4% DRAM BW, Reduce 88.3%).  With the
stacked ``[P, N, D]`` layout the concat disappears; these two kernels fuse the
remaining chain so ``z`` is read from HBM exactly twice (once per pass)
instead of 4-5 times in the unfused chain:

  pass 1: w_p = mean_n( q · tanh(z_p,n W + b) )      (reduction tree -> [P])
  pass 2: out_n = sum_p softmax(w)_p * z_p,n          (weighted reduce)

The softmax over P (a length-P vector) happens on the host side of the two
calls — it is O(P) work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import streaming


def _score_kernel(z_ref, w_ref, b_ref, q_ref, out_ref, *, block_n: int,
                  n_valid: int):
    """Partial semantic scores for one row tile: out [P] += mean-partial."""
    i = pl.program_id(0)
    z = z_ref[...]  # [P, BN, D]
    w = w_ref[...]  # [D, Hs]
    b = b_ref[...]  # [1, Hs]
    q = q_ref[...]  # [1, Hs]
    s = jnp.tanh(z.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32))
    part = (s * q.astype(jnp.float32)).sum(axis=-1)  # [P, BN]
    # pad rows would contribute tanh(b)·q each — mask them out
    j = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    part = jnp.where(i * block_n + j < n_valid, part, 0.0).sum(axis=-1)  # [P]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part[None]

    @pl.when(i != 0)
    def _acc():
        out_ref[...] = out_ref[...] + part[None]


def _combine_kernel(z_ref, beta_ref, out_ref):
    z = z_ref[...]  # [P, BN, D]
    beta = beta_ref[...]  # [1, P]
    out_ref[...] = jnp.einsum(
        "p,pnd->nd", beta[0].astype(jnp.float32), z.astype(jnp.float32)
    ).astype(out_ref.dtype)


def _score_stream_kernel(z_ref, w_ref, b_ref, q_ref, out_ref, buf, sem,
                         *, block_n: int, n: int, n_chunks: int):
    """Pass 1 over an HBM-resident ``z``: double-buffered chunk walk.

    Chunks are consecutive ``[P, block_n, D]`` row slices; the tail chunk is
    aligned to the array end (``off = n - block_n``) so no padded copy of
    ``z`` ever exists — rows a previous chunk already counted are masked out.
    """
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    p = buf.shape[1]

    def off(s):
        return jnp.minimum(s * block_n, n - block_n)

    def dma(slot, s):
        return pltpu.make_async_copy(
            z_ref.at[:, pl.ds(off(s), block_n), :], buf.at[slot], sem.at[slot])

    dma(0, 0).start()

    def body(s, acc):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < n_chunks)  # next chunk in flight
        def _():
            dma(jax.lax.rem(s + 1, 2), s + 1).start()

        dma(slot, s).wait()
        zc = buf[slot].astype(jnp.float32)  # [P, block_n, D]
        sc = jnp.tanh(zc @ w + b)  # [P, block_n, Hs]
        part = (sc * q).sum(axis=-1)  # [P, block_n]
        # tail-overlap dedup: only rows at/after this chunk's logical start
        j = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
        part = jnp.where(j >= s * block_n - off(s), part, 0.0)
        return acc + part.sum(axis=1)

    acc = jax.lax.fori_loop(0, n_chunks, body, jnp.zeros((p,), jnp.float32))
    out_ref[...] = acc[None]


def semantic_scores(
    z: jax.Array, w: jax.Array, b: jax.Array, q: jax.Array,
    block_n: int = 512, interpret: bool = False,
    vmem_budget: int = streaming.VMEM_TABLE_BUDGET,
) -> jax.Array:
    p, n, d = z.shape
    hs = w.shape[1]
    block_n = min(block_n, n)
    oversized = n * p * d * z.dtype.itemsize > vmem_budget
    if oversized and n > block_n:
        # streaming split (as in the NA kernels): z stays in HBM, chunks ride
        # double-buffered DMAs, and — unlike the resident path — no padded
        # whole-array copy of the [P, N, D] stack is ever materialized.
        n_chunks = -(-n // block_n)
        out = pl.pallas_call(
            functools.partial(_score_stream_kernel, block_n=block_n, n=n,
                              n_chunks=n_chunks),
            grid=(1,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),  # z stays in HBM
                pl.BlockSpec((d, hs), lambda i: (0, 0)),
                pl.BlockSpec((1, hs), lambda i: (0, 0)),
                pl.BlockSpec((1, hs), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, p), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
            scratch_shapes=[
                pltpu.VMEM((2, p, block_n, d), z.dtype),  # double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
            interpret=interpret,
        )(z, w, b[None, :], q[None, :])
        return out[0] / n
    n_pad = (-n) % block_n
    if n_pad:  # resident path: pad cost bounded by one tile
        z = jnp.pad(z, ((0, 0), (0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_score_kernel, block_n=block_n, n_valid=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_n, d), lambda i: (0, i, 0)),
            pl.BlockSpec((d, hs), lambda i: (0, 0)),
            pl.BlockSpec((1, hs), lambda i: (0, 0)),
            pl.BlockSpec((1, hs), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(z, w, b[None, :], q[None, :])
    return out[0] / n  # mean over nodes


def semantic_combine(
    z: jax.Array, beta: jax.Array, block_n: int = 512, interpret: bool = False
) -> jax.Array:
    p, n, d = z.shape
    n_pad = (-n) % block_n
    if n_pad:
        z = jnp.pad(z, ((0, 0), (0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_n, d), lambda i: (0, i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), z.dtype),
        interpret=interpret,
    )(z, beta[None, :].astype(jnp.float32))
    return out[:n]


def semantic_attention(
    z: jax.Array, w: jax.Array, b: jax.Array, q: jax.Array,
    block_n: int = 512, interpret: bool = False,
) -> jax.Array:
    wp = semantic_scores(z, w, b, q, block_n=block_n, interpret=interpret)
    beta = jax.nn.softmax(wp)
    return semantic_combine(z, beta, block_n=block_n, interpret=interpret)
