"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>`` in kernels/ has a reference here with identical semantics;
tests sweep shapes/dtypes and assert_allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm(
    h_src: jax.Array,  # [M, D]
    nbr: jax.Array,  # [N, K] int32
    mask: jax.Array,  # [N, K]
    mean: bool = True,
) -> jax.Array:
    """Padded-neighbor sum/mean aggregation (the paper's SpMMCsr analogue)."""
    hn = h_src[nbr]  # [N, K, D]
    s = (hn * mask[..., None].astype(h_src.dtype)).sum(axis=1)
    if mean:
        d = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0).astype(h_src.dtype)
        s = s / d
    return s


def fused_fp_na(
    x_src: jax.Array,  # [M, F] raw features
    w: jax.Array,  # [F, D] projection
    nbr: jax.Array,  # [N, K]
    mask: jax.Array,  # [N, K]
    mean: bool = True,
) -> jax.Array:
    """Guideline (b): fused Feature Projection + Neighbor Aggregation.

    Exploits linearity: aggregate raw features then project once —
    mean_k(x[nbr]) @ W == mean_k(x[nbr] @ W).
    """
    return segment_spmm(x_src, nbr, mask, mean=mean) @ w


def cached_gather(
    table: jax.Array,  # [N, D]
    hot: jax.Array,  # [C] int32 hot row ids
    idx: jax.Array,  # [...] int32 indices into the extended pool [0, N+C)
) -> jax.Array:
    """Hot-row cache gather oracle: the extended pool is the table with the
    hot rows' bitwise copies appended (``kernels/feature_cache.py``)."""
    pool = jnp.concatenate([table, jnp.take(table, hot, axis=0)], axis=0)
    return jnp.take(pool, idx, axis=0)


def gat_na(
    p,  # {"a_dst": [H, Dh], "a_src": [H, Dh]} (leading [S] dim when stacked)
    h_dst: jax.Array,  # [N, H, Dh]
    h_src: jax.Array,  # [M, H, Dh]
    nbr: jax.Array,  # [N, K] int32 ([S, N, K] stacked)
    mask: jax.Array,  # [N, K] {0,1} ([S, N, K] stacked)
) -> jax.Array:
    """Fused multi-head GAT NA oracle: SDDMM + segment-softmax + weighted
    reduce for all heads in one formulation (the kernel's contract)."""
    if nbr.ndim == 3:
        return jax.vmap(lambda pp, nn, mm: gat_na(pp, h_dst, h_src, nn, mm))(
            p, nbr, mask)
    e_dst = (h_dst * p["a_dst"]).sum(-1)  # [N, H]
    e_src = (h_src * p["a_src"]).sum(-1)  # [M, H]
    e = e_dst[:, None, :] + e_src[nbr]  # [N, K, H]  SDDMM
    e = jnp.where(e >= 0, e, 0.2 * e)
    e = jnp.where(mask[..., None] != 0, e, -1e9)
    e = e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True))
    w = jnp.exp(e) * mask[..., None]
    alpha = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    return jnp.einsum("nkh,nkhd->nhd", alpha, h_src[nbr])  # weighted reduce


def gat_na_fused_sa(p, h_dst, h_src, nbr, mask, w, b, q):
    """``gat_na`` with the fused NA→SA epilogue: returns the elu-activated
    NA output plus the per-subgraph semantic-score partial
    ``w_s = mean_n q·tanh(z_s W + b)`` (pass 1 of semantic attention),
    matching the kernel's ``sem=...`` contract."""
    stacked = nbr.ndim == 3
    z = gat_na(p, h_dst, h_src, nbr, mask)
    if not stacked:
        z = z[None]
    z = jax.nn.elu(z)  # [S, N, H, Dh] — the NA activation, fused in-kernel
    s_dim, n = z.shape[0], z.shape[1]
    z2 = z.reshape(s_dim, n, -1)
    sc = jnp.tanh(z2 @ w + b)
    wp = jnp.einsum("snh,h->sn", sc, q).mean(axis=1)  # [S]
    return (z, wp) if stacked else (z[0], wp[0])


def semantic_attention(
    z: jax.Array,  # [P, N, D]
    w: jax.Array,  # [D, Hs]
    b: jax.Array,  # [Hs]
    q: jax.Array,  # [Hs]
) -> jax.Array:
    """HAN semantic attention, concat-free. Matches core.semantics."""
    s = jnp.tanh(z @ w + b)
    wp = jnp.einsum("pnh,h->pn", s, q).mean(axis=1)
    beta = jax.nn.softmax(wp)
    return jnp.einsum("p,pnd->nd", beta, z)


def mha_attention(
    q: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,  # [B, S, KVH, Dh]
    v: jax.Array,  # [B, S, KVH, Dh]
    causal: bool = True,
    window: int = 0,  # 0 = full; else sliding window size
) -> jax.Array:
    """GQA/MHA attention oracle (fp32 softmax)."""
    b_, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b_, s, kvh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    ids = jnp.arange(s)
    m = jnp.ones((s, s), bool)
    if causal:
        m = m & (ids[:, None] >= ids[None, :])
    if window:
        m = m & (ids[:, None] - ids[None, :] < window)
    scores = jnp.where(m, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b_, s, h, dh)


def decode_attention(
    q: jax.Array,  # [B, H, Dh] single new token
    k: jax.Array,  # [B, S, KVH, Dh] cache
    v: jax.Array,  # [B, S, KVH, Dh]
    kv_len: jax.Array | int,  # [B] or scalar: valid cache length
) -> jax.Array:
    b_, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b_, kvh, g, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    valid = jnp.arange(s)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return out.reshape(b_, h, dh)
