"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * On TPU (``use_pallas=True`` in configs) the Pallas kernels run compiled.
  * On CPU (this container, and the multi-pod dry-run) Pallas TPU custom
    calls cannot compile, so wrappers either run ``interpret=True`` (tests)
    or fall back to the pure-jnp reference (dry-run lowering), which is what
    the roofline analysis reads.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import (
    decode_attention as _dec,
    flash_attention as _fa,
    fused_fp_na as _ffn,
    ref,
    segment_spmm as _spmm,
    semantic_attn as _sem,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mean", "use_pallas", "interpret"))
def segment_spmm(h_src, nbr, mask, mean: bool = True, use_pallas: bool = False,
                 interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _spmm.segment_spmm(h_src, nbr, mask, mean=mean, interpret=interpret)
    return ref.segment_spmm(h_src, nbr, mask, mean=mean)


@functools.partial(jax.jit, static_argnames=("mean", "use_pallas", "interpret"))
def fused_fp_na(x_src, w, nbr, mask, mean: bool = True, use_pallas: bool = False,
                interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _ffn.fused_fp_na(x_src, w, nbr, mask, mean=mean, interpret=interpret)
    return ref.fused_fp_na(x_src, w, nbr, mask, mean=mean)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def semantic_attention(z, w, b, q, use_pallas: bool = False, interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _sem.semantic_attention(z, w, b, q, interpret=interpret)
    return ref.semantic_attention(z, w, b, q)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_pallas", "interpret")
)
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_pallas: bool = False, interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=interpret)
    return ref.mha_attention(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k, v, kv_len, use_pallas: bool = False,
                     interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _dec.decode_attention(q, k, v, kv_len, interpret=interpret)
    return ref.decode_attention(q, k, v, kv_len)


def gat_aggregate(p: Dict, h_dst, h_src, nbr, mask, use_pallas: bool = False,
                  interpret: bool = False):
    """GAT NA with the Pallas segment kernel on the weighted-gather hot loop.

    Attention weights (EW-Type math) are computed in XLA; the gather+reduce
    (TB-Type, the paper's dominant cost) runs in the kernel by folding the
    per-edge weight into the mask: sum_k alpha_k * h[nbr_k] ==
    segment_spmm(h, nbr, mask=alpha, mean=False).
    """
    e_dst = (h_dst * p["a_dst"]).sum(-1)  # [N, H]
    e_src = (h_src * p["a_src"]).sum(-1)  # [M, H]
    e = e_dst[:, None, :] + e_src[nbr]  # [N, K, H]
    e = jnp.where(e >= 0, e, 0.2 * e)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    e = e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True))
    w = jnp.exp(e) * mask[..., None]
    alpha = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)  # [N, K, H]
    n, h_heads, dh = h_dst.shape
    outs = []
    for hh in range(h_heads):  # heads loop: small (≤8) static unroll
        outs.append(
            segment_spmm(
                h_src[:, hh, :], nbr, alpha[:, :, hh], mean=False,
                use_pallas=use_pallas, interpret=interpret,
            )
        )
    return jnp.stack(outs, axis=1)  # [N, H, Dh]
