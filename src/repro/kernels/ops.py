"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * On TPU (``use_pallas=True`` in configs) the Pallas kernels run compiled.
  * On CPU (this container, and the multi-pod dry-run) Pallas TPU custom
    calls cannot compile, so wrappers either run ``interpret=True`` (tests)
    or fall back to the pure-jnp reference (dry-run lowering), which is what
    the roofline analysis reads.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import (
    decode_attention as _dec,
    feature_cache as _fc,
    flash_attention as _fa,
    fused_fp_na as _ffn,
    gat_na as _gat,
    ref,
    segment_spmm as _spmm,
    semantic_attn as _sem,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mean", "use_pallas", "interpret"))
def segment_spmm(h_src, nbr, mask, mean: bool = True, use_pallas: bool = False,
                 interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _spmm.segment_spmm(h_src, nbr, mask, mean=mean, interpret=interpret)
    return ref.segment_spmm(h_src, nbr, mask, mean=mean)


@functools.partial(jax.jit, static_argnames=("mean", "use_pallas", "interpret"))
def fused_fp_na(x_src, w, nbr, mask, mean: bool = True, use_pallas: bool = False,
                interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _ffn.fused_fp_na(x_src, w, nbr, mask, mean=mean, interpret=interpret)
    return ref.fused_fp_na(x_src, w, nbr, mask, mean=mean)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def semantic_attention(z, w, b, q, use_pallas: bool = False, interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _sem.semantic_attention(z, w, b, q, interpret=interpret)
    return ref.semantic_attention(z, w, b, q)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_pallas", "interpret")
)
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_pallas: bool = False, interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=interpret)
    return ref.mha_attention(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k, v, kv_len, use_pallas: bool = False,
                     interpret: bool = False):
    if use_pallas and (_on_tpu() or interpret):
        return _dec.decode_attention(q, k, v, kv_len, interpret=interpret)
    return ref.decode_attention(q, k, v, kv_len)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cached_gather(table, hot, idx, use_pallas: bool = False,
                  interpret: bool = False):
    """Hot-row cache gather (``repro.core.residency``): reads from the
    extended pool ``concat(table, table[hot])`` with the cache section
    VMEM-resident on the Pallas path (kernels/feature_cache.py).  Indices
    ``>= len(table)`` hit the cache; the rest gather from HBM."""
    if use_pallas and (_on_tpu() or interpret):
        return _fc.cached_gather(table, hot, idx, interpret=interpret)
    return ref.cached_gather(table, hot, idx)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gat_aggregate(p: Dict, h_dst, h_src, nbr, mask, use_pallas: bool = False,
                  interpret: bool = False):
    """Fused multi-head GAT NA: SDDMM + segment-softmax + weighted reduce in
    ONE kernel launch for all heads (kernels/gat_na.py).

    Replaces the seed's split execution (edge scores in XLA re-gathering
    ``h_src[nbr]``, then one ``segment_spmm`` launch per head): the neighbor
    tile is gathered exactly once and every head rides the same gather.
    Large source tables stream from HBM instead of falling back to the ref.
    """
    if use_pallas and (_on_tpu() or interpret):
        return _gat.gat_na(p, h_dst, h_src, nbr, mask, interpret=interpret)
    return ref.gat_na(p, h_dst, h_src, nbr, mask)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gat_aggregate_stacked(p_stacked: Dict, h_dst, h_src, nbr, mask,
                          use_pallas: bool = False, interpret: bool = False):
    """Stacked form: ``nbr/mask [P, N, K]``, params ``[P, H, Dh]`` — the whole
    metapath stack (HAN's inter-subgraph parallelism) is ONE kernel launch
    (the stack dim rides the Pallas grid), not P launches of H kernels."""
    if use_pallas and (_on_tpu() or interpret):
        return _gat.gat_na(p_stacked, h_dst, h_src, nbr, mask,
                           interpret=interpret)
    return ref.gat_na(p_stacked, h_dst, h_src, nbr, mask)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gat_aggregate_stacked_fused_sa(p_stacked: Dict, h_dst, h_src, nbr, mask,
                                   sem: Dict, use_pallas: bool = False,
                                   interpret: bool = False):
    """Stacked GAT NA with the fused NA→SA epilogue (inter-stage reuse):
    the semantic-score pass-1 partial accumulates inside the NA kernel while
    each ``z`` tile is still in VMEM, so SA never re-reads the ``[P, N, D]``
    stack for its scores.  Returns ``(z [P, N, H, Dh] elu-activated, w [P])``.
    """
    if use_pallas and (_on_tpu() or interpret):
        return _gat.gat_na(p_stacked, h_dst, h_src, nbr, mask,
                           interpret=interpret, sem=sem)
    return ref.gat_na_fused_sa(p_stacked, h_dst, h_src, nbr, mask,
                               sem["W"], sem["b"], sem["q"])


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def semantic_combine(z, beta, use_pallas: bool = False,
                     interpret: bool = False):
    """SA pass 2 only (the fused-epilogue path's remaining work): weighted
    combine ``sum_p beta_p z_p`` — exactly one read of the stack."""
    if use_pallas and (_on_tpu() or interpret):
        return _sem.semantic_combine(z, beta, interpret=interpret)
    return jnp.einsum("p,pnd->nd", beta, z)
