"""Pallas TPU kernel: single-token decode attention over a long KV cache.

Flash-decoding structure: grid = (batch, kv_blocks); each step loads a KV
tile into VMEM, computes partial online-softmax statistics for ALL query
heads at once (GQA: [KVH, G] head layout so the einsum hits the MXU), and
accumulates in scratch.  The kv axis is "arbitrary" so scratch carries across
steps; output is written on the last step.

This is the serve_step hot kernel for decode_32k / long_500k shapes; the
sharded variant splits the kv axis over the 'model' mesh axis outside the
kernel (see serve/decode.py) and combines partials with the same online rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_k: int, scale: float,
):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # [H, Dh]
        k = k_ref[0].astype(jnp.float32)  # [BK, KVH, Dh]
        v = v_ref[0].astype(jnp.float32)  # [BK, KVH, Dh]
        h, dh = q.shape
        kvh = k.shape[1]
        g = h // kvh
        qg = q.reshape(kvh, g, dh)
        s = jnp.einsum("kgd,tkd->kgt", qg, k)  # [KVH, G, BK]
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_prev = m_scr[...]  # [H, 1]
        m_cur = jnp.maximum(m_prev[:, 0], s.max(axis=-1).reshape(h))[:, None]
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur.reshape(kvh, g, 1))
        p = jnp.where(cols < kv_len, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1).reshape(h, 1)
        pv = jnp.einsum("kgt,tkd->kgd", p, v).reshape(h, dh)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [B, H, Dh]
    k: jax.Array,  # [B, S, KVH, Dh]
    v: jax.Array,  # [B, S, KVH, Dh]
    kv_len,  # [B] int32 valid lengths (or scalar)
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    block_k = min(block_k, s)
    assert s % block_k == 0
    grid = (b, s // block_k)
    scale = 1.0 / (dh ** 0.5)
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki: (bi,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, h, dh), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, dh), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, dh), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bi, ki: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_len, q, k, v)
    return out
