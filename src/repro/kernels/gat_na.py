"""Pallas TPU kernel: fused multi-head GAT Neighbor Aggregation.

The paper's NA stage is the dominant cost (74% of HGNN inference) and on GPU
decomposes into three kernels — SDDMM edge scores, segment-softmax, SpMM
weighted gather — re-reading the edge list and re-gathering source rows in
each.  The seed code mirrored that split: edge scores in XLA (one gather of
the source table), then one ``segment_spmm`` launch *per attention head*
(H more gathers).  This kernel collapses the whole stage into a single
launch per metapath stack:

  per ``[block_n, K]`` destination tile, for ALL heads at once:
    1. SDDMM   — ``e[n,k,h] = leaky_relu(a_dst·h_dst[n,h] + a_src·h_src[nbr])``
    2. softmax — masked segment-softmax over the K neighbor slots
    3. reduce  — K-step weighted reduction tree into ``[BN, H, Dh]``

The neighbor tile is gathered exactly once: each gathered source row feeds
both its edge score and its weighted contribution.  The softmax is *online*
(flash-attention style: running max / denominator / rescaled accumulator), so
the source table can be consumed in chunks without a second pass.

Two execution paths share the same tile update:

* **resident** — the source table fits VMEM (one BlockSpec, kept across
  tiles by the Pallas pipeline).  This is the common case for HGNN latent
  tables (4k x 64 ~ 1 MB).
* **streaming** — the table stays in HBM; a scalar-prefetched chunk schedule
  (``pltpu.PrefetchScalarGridSpec``) drives double-buffered
  ``pltpu.make_async_copy`` DMAs, overlapping the fetch of chunk ``s+1``
  with the reduction over chunk ``s`` (see ``kernels/streaming.py``).

An optional leading stack dim ``S`` (HAN's per-metapath subgraphs, stacked
``[P, N, K]``) rides the grid, so the *entire* metapath stack is one
``pallas_call`` — no per-head and no per-metapath Python loop.

Layout note: features travel as 2-D ``[rows, H*Dh]`` tiles (lane-friendly)
and reshape to ``[rows, H, Dh]`` inside the kernel for the per-head math;
``mask`` is {0,1}-valued (GAT edge presence), matching ``ref.gat_na``.

**Fused NA→SA epilogue** (``sem=...``): the paper's inter-stage-reuse
guideline.  Semantic Aggregation's pass 1 (``w_p = mean_n q·tanh(z_p W + b)``,
see kernels/semantic_attn.py) re-reads the whole ``[P, N, D]`` NA output from
HBM.  With ``sem`` given, each output tile is activated (elu) and folded into
the per-subgraph score partial *while still in VMEM* — the kernel returns
``(z, w)`` and SA shrinks to a length-P softmax plus the weighted combine,
eliminating one full HBM pass over the stack.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import streaming

_NEG = -1e9


def _tile_update(carry, nbr, mask, e_dst, a_src, hbuf, lo, n_heads: int):
    """Online-softmax update of one destination tile against one source chunk.

    carry: (acc [BN,H,Dh] f32, denom [BN,H] f32, m_run [BN,H] f32)
    hbuf:  [BM, H*Dh] chunk of the source table whose global rows are
           ``[lo, lo+BM)``; SDDMM + weighted reduce both read it once.
    """
    acc, denom, m_run = carry
    bm, hdh = hbuf.shape
    dh = hdh // n_heads
    h3 = hbuf.reshape(bm, n_heads, dh).astype(jnp.float32)
    e_src = (h3 * a_src).sum(-1)  # [BM, H]  (SDDMM source half)
    sel = (nbr >= lo) & (nbr < lo + bm) & (mask != 0)  # [BN, K]
    loc = jnp.where(sel, nbr - lo, 0)
    k = nbr.shape[1]
    scores = []
    for j in range(k):  # K-step reduction tree, step 1: scores
        e = e_dst + jnp.take(e_src, loc[:, j], axis=0)  # [BN, H]
        e = jnp.where(e >= 0, e, 0.2 * e)  # leaky relu
        scores.append(jnp.where(sel[:, j][:, None], e, _NEG))
    e_chunk = jnp.stack(scores, axis=1)  # [BN, K, H]
    m_new = jnp.maximum(m_run, e_chunk.max(axis=1))
    scale = jnp.exp(m_run - m_new)
    p_w = jnp.exp(e_chunk - m_new[:, None, :]) * sel[..., None]  # [BN, K, H]
    denom = denom * scale + p_w.sum(axis=1)
    acc = acc * scale[..., None]
    for j in range(k):  # K-step reduction tree, step 2: weighted gather
        acc = acc + p_w[:, j, :, None] * jnp.take(h3, loc[:, j], axis=0)
    return acc, denom, m_new


def _init_carry(bn: int, n_heads: int, dh: int):
    return (jnp.zeros((bn, n_heads, dh), jnp.float32),
            jnp.zeros((bn, n_heads), jnp.float32),
            jnp.full((bn, n_heads), _NEG, jnp.float32))


def _finish(carry):
    acc, denom, _ = carry
    out = acc / jnp.maximum(denom, 1e-9)[..., None]
    return out.reshape(out.shape[0], -1)  # [BN, H*Dh] f32


def _write(out2d, out_ref):
    out_ref[...] = out2d.astype(out_ref.dtype)[None]


def _sa_epilogue(out2d, w_ref, b_ref, q_ref, out_ref, scores_ref,
                 block_n: int, n_valid: int):
    """Activate the tile and fold SA pass 1 into it while it sits in VMEM.

    Writes ``z = elu(out)`` and accumulates ``sum_n q·tanh(z W + b)`` for
    this subgraph into ``scores_ref`` across the row-tile grid dim; rows past
    ``n_valid`` (the block_n pad) contribute nothing.
    """
    t = pl.program_id(1)
    z = jnp.where(out2d > 0, out2d, jnp.expm1(out2d))  # elu (NA activation)
    _write(z, out_ref)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)  # [1, Hs]
    q = q_ref[...].astype(jnp.float32)  # [1, Hs]
    s = jnp.tanh(z @ w + b)  # [BN, Hs]
    part = (s * q).sum(axis=-1, keepdims=True)  # [BN, 1]
    rows = t * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (z.shape[0], 1), 0)
    part = jnp.where(rows < n_valid, part, 0.0).sum()

    @pl.when(t == 0)
    def _init():
        scores_ref[...] = jnp.full((1, 1), part, jnp.float32)

    @pl.when(t != 0)
    def _acc():
        scores_ref[...] = scores_ref[...] + part


def _edst(hdst, a_dst, n_heads: int):
    bn, hdh = hdst.shape
    h3 = hdst.reshape(bn, n_heads, hdh // n_heads).astype(jnp.float32)
    return (h3 * a_dst).sum(-1)  # [BN, H]  (SDDMM destination half)


def _resident_kernel(nbr_ref, mask_ref, hdst_ref, adst_ref, asrc_ref,
                     hsrc_ref, *rest, n_heads: int, block_n: int = 0,
                     n_valid: int = 0, fuse_sa: bool = False):
    if fuse_sa:
        w_ref, b_ref, q_ref, out_ref, scores_ref = rest
    else:
        (out_ref,) = rest
    nbr = nbr_ref[0]
    mask = mask_ref[0]
    a_dst = adst_ref[0].astype(jnp.float32)
    a_src = asrc_ref[0].astype(jnp.float32)
    e_dst = _edst(hdst_ref[...], a_dst, n_heads)
    bn = nbr.shape[0]
    dh = hdst_ref.shape[1] // n_heads
    carry = _tile_update(_init_carry(bn, n_heads, dh), nbr, mask, e_dst,
                         a_src, hsrc_ref[...], 0, n_heads)
    out2d = _finish(carry)
    if fuse_sa:
        _sa_epilogue(out2d, w_ref, b_ref, q_ref, out_ref, scores_ref,
                     block_n, n_valid)
    else:
        _write(out2d, out_ref)


def _streaming_kernel(sched_ref, count_ref, nbr_ref, mask_ref, hdst_ref,
                      adst_ref, asrc_ref, hsrc_ref, *rest,
                      n_heads: int, block_m: int, block_n: int = 0,
                      n_valid: int = 0, fuse_sa: bool = False):
    if fuse_sa:
        w_ref, b_ref, q_ref, out_ref, scores_ref, buf, sem = rest
    else:
        out_ref, buf, sem = rest
    st = pl.program_id(0) * pl.num_programs(1) + pl.program_id(1)
    nc = count_ref[st]
    nbr = nbr_ref[0]
    mask = mask_ref[0]
    a_dst = adst_ref[0].astype(jnp.float32)
    a_src = asrc_ref[0].astype(jnp.float32)
    e_dst = _edst(hdst_ref[...], a_dst, n_heads)
    bn = nbr.shape[0]
    dh = hdst_ref.shape[1] // n_heads

    def get_dma(slot, s):
        c = sched_ref[st, s]
        return pltpu.make_async_copy(
            hsrc_ref.at[pl.ds(c * block_m, block_m), :], buf.at[slot],
            sem.at[slot])

    @pl.when(nc > 0)
    def _warmup():
        get_dma(0, 0).start()

    def body(s, carry):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < nc)  # double buffer: next chunk in flight
        def _():
            get_dma(jax.lax.rem(s + 1, 2), s + 1).start()

        get_dma(slot, s).wait()
        lo = sched_ref[st, s] * block_m
        return _tile_update(carry, nbr, mask, e_dst, a_src, buf[slot], lo,
                            n_heads)

    carry = jax.lax.fori_loop(0, nc, body, _init_carry(bn, n_heads, dh))
    out2d = _finish(carry)
    if fuse_sa:
        _sa_epilogue(out2d, w_ref, b_ref, q_ref, out_ref, scores_ref,
                     block_n, n_valid)
    else:
        _write(out2d, out_ref)


def _normalize(p: Dict, h_dst, h_src, nbr, mask) -> Tuple:
    """Lift the unstacked call form to the stacked one (S=1)."""
    if nbr.ndim == 2:
        return ({k: v[None] for k, v in p.items()}, h_dst, h_src,
                nbr[None], mask[None], False)
    return p, h_dst, h_src, nbr, mask, True


def gat_na(
    p: Dict[str, jax.Array],  # a_dst/a_src [H, Dh] (or [S, H, Dh] stacked)
    h_dst: jax.Array,  # [N, H, Dh]
    h_src: jax.Array,  # [M, H, Dh]
    nbr: jax.Array,  # [N, K] int32 (or [S, N, K] stacked)
    mask: jax.Array,  # [N, K] {0,1} float (or [S, N, K])
    block_n: int = 128,
    block_m: int = 0,  # 0 = auto (resident if the table fits, else 512)
    vmem_budget: int = streaming.VMEM_TABLE_BUDGET,
    interpret: bool = False,
    sem=None,  # {"W" [H*Dh, Hs], "b" [Hs], "q" [Hs]}: fused NA→SA epilogue
) -> jax.Array:
    """Fused multi-head GAT NA; one launch per (stacked) subgraph batch.

    Returns ``[N, H, Dh]`` (``[S, N, H, Dh]`` for the stacked form).  With
    ``sem`` the output is elu-activated and the SA pass-1 score partial is
    accumulated in the same launch; returns ``(z, w [S])`` (``(z, w)``
    scalars for the unstacked form) where ``w_s = mean_n q·tanh(z_s W + b)``.
    """
    p, h_dst, h_src, nbr, mask, stacked = _normalize(p, h_dst, h_src, nbr, mask)
    s_dim, n, k = nbr.shape
    m, n_heads, dh = h_src.shape
    hdh = n_heads * dh
    h_dst2 = streaming.pad_rows(h_dst.reshape(-1, hdh), block_n)
    h_src2 = h_src.reshape(m, hdh)
    n_pad = (-n) % block_n
    if n_pad:
        nbr = jnp.pad(nbr, ((0, 0), (0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, n_pad), (0, 0)))
    nbr = nbr.astype(jnp.int32)
    n_tiles = (n + n_pad) // block_n
    a_dst = p["a_dst"].astype(jnp.float32)
    a_src = p["a_src"].astype(jnp.float32)

    resident = block_m == 0 and streaming.table_fits_vmem(
        m, hdh * h_src2.dtype.itemsize, vmem_budget)
    fuse_sa = sem is not None
    extra_in: list = []
    if fuse_sa:
        hs = sem["W"].shape[1]
        extra_in = [sem["W"].astype(jnp.float32),
                    sem["b"].astype(jnp.float32)[None, :],
                    sem["q"].astype(jnp.float32)[None, :]]
    out_shape = jax.ShapeDtypeStruct((s_dim, n + n_pad, hdh), h_dst.dtype)
    out_spec = pl.BlockSpec((1, block_n, hdh), lambda s, t: (s, t, 0))
    if fuse_sa:
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((s_dim, 1), jnp.float32)]
        out_spec = [out_spec, pl.BlockSpec((1, 1), lambda s, t: (s, 0))]
    row_specs = [
        pl.BlockSpec((1, block_n, k), lambda s, t: (s, t, 0)),  # nbr
        pl.BlockSpec((1, block_n, k), lambda s, t: (s, t, 0)),  # mask
        pl.BlockSpec((block_n, hdh), lambda s, t: (t, 0)),      # h_dst
        pl.BlockSpec((1, n_heads, dh), lambda s, t: (s, 0, 0)),  # a_dst
        pl.BlockSpec((1, n_heads, dh), lambda s, t: (s, 0, 0)),  # a_src
    ]
    sem_specs = [
        pl.BlockSpec((hdh, hs), lambda s, t: (0, 0)),  # W
        pl.BlockSpec((1, hs), lambda s, t: (0, 0)),    # b
        pl.BlockSpec((1, hs), lambda s, t: (0, 0)),    # q
    ] if fuse_sa else []
    kern_kw = dict(n_heads=n_heads, fuse_sa=fuse_sa, block_n=block_n,
                   n_valid=n)

    if resident:
        out = pl.pallas_call(
            functools.partial(_resident_kernel, **kern_kw),
            grid=(s_dim, n_tiles),
            in_specs=(row_specs
                      + [pl.BlockSpec((m, hdh), lambda s, t: (0, 0))]
                      + sem_specs),
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(nbr, mask, h_dst2, a_dst, a_src, h_src2, *extra_in)
    else:
        if block_m == 0:
            block_m = 512
        block_m = min(block_m, max(m, 1))
        h_src2 = streaming.pad_rows(h_src2, block_m)
        n_chunks = h_src2.shape[0] // block_m
        sched, count = streaming.chunk_schedule(
            nbr.reshape(-1, k), mask.reshape(-1, k), block_n, n_chunks, block_m)

        def drop_sched(spec):
            """Lift a (s, t) index map over the scalar-prefetch operands."""
            return pl.BlockSpec(spec.block_shape,
                                lambda s, t, *_: spec.index_map(s, t))

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s_dim, n_tiles),
            in_specs=([drop_sched(sp) for sp in row_specs]
                      + [pl.BlockSpec(memory_space=pltpu.ANY)]  # h_src in HBM
                      + [drop_sched(sp) for sp in sem_specs]),
            out_specs=([drop_sched(sp) for sp in out_spec] if fuse_sa
                       else drop_sched(out_spec)),
            scratch_shapes=[
                pltpu.VMEM((2, block_m, hdh), h_src2.dtype),  # double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        out = pl.pallas_call(
            functools.partial(_streaming_kernel, block_m=block_m, **kern_kw),
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(sched, count, nbr, mask, h_dst2, a_dst, a_src, h_src2, *extra_in)

    if fuse_sa:
        out, scores = out
        out = out[:, :n].reshape(s_dim, n, n_heads, dh)
        wp = scores[:, 0] / n  # mean over (valid) nodes
        return (out, wp) if stacked else (out[0], wp[0])
    out = out[:, :n].reshape(s_dim, n, n_heads, dh)
    return out if stacked else out[0]
