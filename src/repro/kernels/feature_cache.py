"""Pallas TPU kernel: hot-row feature-cache gather (residency fast path).

``repro.core.residency`` remaps every NA index table so references to hot
rows address a contiguous cache section appended to the source pool
(``pool = concat(table, table[hot])``, indices ``>= N``).  This kernel
serves those remapped gathers with the cache section pinned in VMEM:

* the ``[C, D]`` cache block has a constant index map, so the Pallas
  pipeline keeps it resident across every index tile (the same
  whole-table-resident idiom as ``segment_spmm``'s small-table path) —
  a hot reference never touches HBM again;
* cold references fall through to a plain XLA gather of the HBM table.

Bit-exactness: the cache rows are bitwise copies of table rows, the
in-kernel ``take`` moves them unscaled (the ``* 1.0`` validity mask is
exact), and the hot/cold merge is a ``where`` — so the result equals
``concat(table, table[hot])[idx]`` bit for bit (``ref.cached_gather``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(slot_ref, cache_ref, out_ref):
    slot = slot_ref[...][:, 0]  # [BN] cache slot per index (-1 = cold)
    cache = cache_ref[...]  # [C, D] — VMEM-resident across tiles
    rows = jnp.take(cache, jnp.clip(slot, 0, cache.shape[0] - 1), axis=0)
    valid = (slot >= 0).astype(cache.dtype)[:, None]
    out_ref[...] = rows * valid  # cold rows zero; merged outside


def cached_gather(
    table: jax.Array,  # [N, D] source feature table (HBM)
    hot: jax.Array,  # [C] int32 hot row ids (the cache section's contents)
    idx: jax.Array,  # [...] int32 indices into the extended pool [0, N+C)
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gather from ``concat(table, table[hot])`` with the cache in VMEM."""
    n, d = table.shape
    c = hot.shape[0]
    cache = jnp.take(table, hot.astype(jnp.int32), axis=0)  # [C, D] fill
    flat = idx.reshape(-1).astype(jnp.int32)
    m = flat.shape[0]
    pad = (-m) % block_n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    slot = jnp.where(flat >= n, flat - n, -1).reshape(-1, 1)
    hot_rows = pl.pallas_call(
        _kernel,
        grid=((m + pad) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),  # resident cache section
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, d), table.dtype),
        interpret=interpret,
    )(slot, cache)[:m]
    flat = flat[:m]
    cold_rows = jnp.take(table, jnp.where(flat < n, flat, 0), axis=0)
    out = jnp.where((flat >= n)[:, None], hot_rows, cold_rows)
    return out.reshape(idx.shape + (d,))
