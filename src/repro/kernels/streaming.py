"""HBM-streaming support for the Neighbor Aggregation kernels.

The NA kernels (``gat_na``, ``segment_spmm``, ``fused_fp_na``) gather rows of
a source feature table with data-dependent indices.  Small tables live whole
in VMEM (one BlockSpec, the pipeline keeps them resident across row tiles);
large tables cannot, and the seed code silently fell back to the XLA ref.

The streaming path lifts that limit.  The source table stays in HBM
(``memory_space=ANY``); the wrapper pre-computes, per destination row tile,
*which* ``block_m``-row chunks of the table its neighbor ids touch — the
**chunk schedule** — and passes it as a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``).  Inside the kernel a double-buffered
``pltpu.make_async_copy`` loop walks the schedule: the DMA for chunk ``s+1``
is in flight while chunk ``s`` is gathered/reduced, so HBM latency hides
behind the VPU reduction tree.  Chunks no neighbor touches are never fetched
— for power-law graphs most tiles touch a small fraction of the table.

Everything here is jit-traceable (static shapes only): the schedule is built
with one ``segment_max`` scatter + one sort, no host round-trip.

Scaling envelope: the schedule is ``[n_tiles, n_chunks]`` int32 and rides the
scalar-prefetch operand whole, so its footprint grows as
``(N / block_n) * (M / block_m)``.  That is fine for the HGNN working set
this repo targets (thousands of tiles x tens of chunks); for web-scale
tables the schedule itself outgrows SMEM and wants per-tile blocking
(``BlockSpec(..., memory_space=SMEM)`` rows instead of one prefetched
array) — tracked in ROADMAP.md under the real-TPU validation item.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Source tables at or under this many bytes stay whole-in-VMEM (resident
# BlockSpec path); larger ones stream.  Half of a v5e core's 16 MB VMEM,
# leaving room for the row tile, schedule buffers and double buffers.
VMEM_TABLE_BUDGET = 8 * 1024 * 1024


def table_fits_vmem(m: int, row_bytes: int, budget: int = VMEM_TABLE_BUDGET) -> bool:
    """Static (trace-time) residency decision for an ``[m, ...]`` table."""
    return m * row_bytes <= budget


def chunk_schedule(
    nbr: jax.Array,  # [N, K] int32 (row-padded to a tile multiple)
    mask: jax.Array,  # [N, K] float; 0 = padded / absent edge
    block_n: int,
    n_chunks: int,
    block_m: int,
) -> Tuple[jax.Array, jax.Array]:
    """Per-tile chunk schedule: which source chunks each row tile touches.

    Returns ``(sched [T, C] int32, count [T] int32)`` where for tile ``t``
    the first ``count[t]`` entries of ``sched[t]`` are the touched chunk ids
    in ascending order (remaining entries are 0 and must not be read).
    """
    n = nbr.shape[0]
    n_tiles = n // block_n
    chunk = nbr.astype(jnp.int32) // block_m  # [N, K]
    valid = (mask != 0).astype(jnp.int32)
    tile = (jnp.arange(n, dtype=jnp.int32) // block_n)[:, None]  # [N, 1]
    flat = (tile * n_chunks + chunk).reshape(-1)
    touched = jax.ops.segment_max(
        valid.reshape(-1), flat, num_segments=n_tiles * n_chunks
    ).reshape(n_tiles, n_chunks) > 0
    # touched ids ascending, untouched pushed past the end via a sentinel
    key = jnp.where(touched, jnp.arange(n_chunks, dtype=jnp.int32)[None, :],
                    jnp.int32(n_chunks))
    sched = jnp.sort(key, axis=1)
    count = touched.sum(axis=1).astype(jnp.int32)
    sched = jnp.where(sched >= n_chunks, 0, sched).astype(jnp.int32)
    return sched, count


def pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad the leading dim of ``x`` up to a multiple (DMA chunks must be
    full-size; padded rows are never selected by the in-chunk mask)."""
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


class InflightWindow:
    """Bounded async-dispatch window for the stage-graph schedule.

    The host-side analogue of this module's double-buffered DMA loop: the
    kernel keeps the DMA for chunk ``s+1`` in flight while chunk ``s``
    reduces; the :meth:`~repro.core.pipeline.StageGraphExecutor.
    forward_overlapped` driver keeps up to ``depth`` *stage results* in
    flight on JAX's async dispatch stream while the host races ahead to
    issue dependents.  Admitting one more stage past the window blocks on
    the oldest (the DMA wait of slot ``s - depth``); ``depth <= 1`` is the
    serial schedule — every admit blocks immediately, which is the bit-exact
    parity baseline the tests pin.
    """

    def __init__(self, depth: int):
        self.depth = max(int(depth), 1)
        self._live: list = []
        self.admitted: list = []
        self.max_inflight = 0

    def admit(self, name: str, value):
        """Record ``value`` (a dispatched stage's output pytree) as in
        flight; blocks until the window has room for it."""
        self.admitted.append(name)
        if self.depth <= 1:
            jax.block_until_ready(value)
            self.max_inflight = max(self.max_inflight, 1)
            return value
        self._live.append(value)
        self.max_inflight = max(self.max_inflight, len(self._live))
        while len(self._live) > self.depth:
            jax.block_until_ready(self._live.pop(0))
        return value

    def drain(self) -> None:
        while self._live:
            jax.block_until_ready(self._live.pop(0))
