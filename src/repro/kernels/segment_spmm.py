"""Pallas TPU kernel: padded-neighbor SpMM (Neighbor Aggregation hot loop).

The paper's NA hot kernel is ``SpMMCsr`` — irregular CSR gather + reduce,
74% DRAM BW / 31% L2 hit on the T4.  TPUs have no efficient warp-level
scatter, so the TPU-native formulation is a *degree-capped padded* layout
``nbr[N, K]``: the irregular reduction becomes a K-step reduction tree over
dense VMEM tiles (guideline (d): reduction-tree dataflow).

Blocking: grid over row tiles of size ``block_n``; the neighbor-id tile and
mask tile live in VMEM; the source feature table ``h_src`` is kept whole in
VMEM (HGNN latent tables are small: N×D ≈ 4k×64 ≈ 1 MB ≪ 16 MB v5e VMEM).
For tables that exceed VMEM the wrapper falls back to the XLA path — noted in
ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_ref, mask_ref, hsrc_ref, out_ref, *, mean: bool):
    nbr = nbr_ref[...]  # [BN, K] int32
    mask = mask_ref[...]  # [BN, K]
    h = hsrc_ref[...]  # [M, D] (whole table in VMEM)
    k = nbr.shape[1]
    acc = jnp.zeros((nbr.shape[0], h.shape[1]), jnp.float32)
    # K-step reduction tree: each step is a dense row-gather + masked add.
    for j in range(k):
        rows = jnp.take(h, nbr[:, j], axis=0)  # [BN, D]
        acc = acc + rows.astype(jnp.float32) * mask[:, j][:, None].astype(jnp.float32)
    if mean:
        deg = jnp.maximum(mask.astype(jnp.float32).sum(axis=1, keepdims=True), 1.0)
        acc = acc / deg
    out_ref[...] = acc.astype(out_ref.dtype)


def segment_spmm(
    h_src: jax.Array,
    nbr: jax.Array,
    mask: jax.Array,
    mean: bool = True,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, k = nbr.shape
    m, d = h_src.shape
    n_pad = (-n) % block_n
    if n_pad:
        nbr = jnp.pad(nbr, ((0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, mean=mean),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),  # whole feature table
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), h_src.dtype),
        interpret=interpret,
    )(nbr, mask, h_src)
    return out[:n]
