"""Pallas TPU kernel: padded-neighbor SpMM (Neighbor Aggregation hot loop).

The paper's NA hot kernel is ``SpMMCsr`` — irregular CSR gather + reduce,
74% DRAM BW / 31% L2 hit on the T4.  TPUs have no efficient warp-level
scatter, so the TPU-native formulation is a *degree-capped padded* layout
``nbr[N, K]``: the irregular reduction becomes a K-step reduction tree over
dense VMEM tiles (guideline (d): reduction-tree dataflow).

Blocking: grid over row tiles of size ``block_n``; the neighbor-id tile and
mask tile live in VMEM.  The source feature table has two paths:

* **resident** — small tables (HGNN latent: N x D ~ 4k x 64 ~ 1 MB) are one
  whole-table BlockSpec; the Pallas pipeline keeps them in VMEM across tiles.
* **streaming** — larger tables stay in HBM; a scalar-prefetched chunk
  schedule drives double-buffered ``make_async_copy`` DMAs and each chunk is
  gathered via an in-chunk mask (see ``kernels/streaming.py``).  No more
  silent fallback to the XLA ref for big graphs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import streaming


def _accumulate(acc, nbr, mask, hbuf, lo):
    """Masked K-step gather-reduce of one source chunk into ``acc``."""
    bm = hbuf.shape[0]
    in_chunk = (nbr >= lo) & (nbr < lo + bm)
    loc = jnp.where(in_chunk, nbr - lo, 0)
    w = mask.astype(jnp.float32) * in_chunk.astype(jnp.float32)
    k = nbr.shape[1]
    for j in range(k):  # K-step reduction tree
        rows = jnp.take(hbuf, loc[:, j], axis=0)
        acc = acc + rows.astype(jnp.float32) * w[:, j][:, None]
    return acc


def _mean(acc, mask, mean: bool):
    if mean:
        deg = jnp.maximum(mask.astype(jnp.float32).sum(axis=1, keepdims=True),
                          1.0)
        acc = acc / deg
    return acc


def _kernel(nbr_ref, mask_ref, hsrc_ref, out_ref, *, mean: bool):
    nbr = nbr_ref[...]  # [BN, K] int32
    mask = mask_ref[...]  # [BN, K]
    acc = jnp.zeros((nbr.shape[0], hsrc_ref.shape[1]), jnp.float32)
    acc = _accumulate(acc, nbr, mask, hsrc_ref[...], 0)
    out_ref[...] = _mean(acc, mask, mean).astype(out_ref.dtype)


def _stream_kernel(sched_ref, count_ref, nbr_ref, mask_ref, hsrc_ref, out_ref,
                   buf, sem, *, mean: bool, block_m: int):
    t = pl.program_id(0)
    nc = count_ref[t]
    nbr = nbr_ref[...]
    mask = mask_ref[...]

    def get_dma(slot, s):
        c = sched_ref[t, s]
        return pltpu.make_async_copy(
            hsrc_ref.at[pl.ds(c * block_m, block_m), :], buf.at[slot],
            sem.at[slot])

    @pl.when(nc > 0)
    def _warmup():
        get_dma(0, 0).start()

    def body(s, acc):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < nc)  # double buffer: next chunk in flight
        def _():
            get_dma(jax.lax.rem(s + 1, 2), s + 1).start()

        get_dma(slot, s).wait()
        lo = sched_ref[t, s] * block_m
        return _accumulate(acc, nbr, mask, buf[slot], lo)

    acc0 = jnp.zeros((nbr.shape[0], out_ref.shape[1]), jnp.float32)
    acc = jax.lax.fori_loop(0, nc, body, acc0)
    out_ref[...] = _mean(acc, mask, mean).astype(out_ref.dtype)


def segment_spmm(
    h_src: jax.Array,
    nbr: jax.Array,
    mask: jax.Array,
    mean: bool = True,
    block_n: int = 128,
    block_m: int = 0,  # 0 = auto (resident if the table fits, else 512)
    vmem_budget: int = streaming.VMEM_TABLE_BUDGET,
    interpret: bool = False,
) -> jax.Array:
    n, k = nbr.shape
    m, d = h_src.shape
    n_pad = (-n) % block_n
    if n_pad:
        nbr = jnp.pad(nbr, ((0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad), (0, 0)))
    nbr = nbr.astype(jnp.int32)
    grid = ((n + n_pad) // block_n,)
    out_shape = jax.ShapeDtypeStruct((n + n_pad, d), h_src.dtype)

    resident = block_m == 0 and streaming.table_fits_vmem(
        m, d * h_src.dtype.itemsize, vmem_budget)
    if resident:
        out = pl.pallas_call(
            functools.partial(_kernel, mean=mean),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, k), lambda i: (i, 0)),
                pl.BlockSpec((block_n, k), lambda i: (i, 0)),
                pl.BlockSpec((m, d), lambda i: (0, 0)),  # whole feature table
            ],
            out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(nbr, mask, h_src)
        return out[:n]

    if block_m == 0:
        block_m = 512
    block_m = min(block_m, max(m, 1))
    h_src = streaming.pad_rows(h_src, block_m)
    n_chunks = h_src.shape[0] // block_m
    sched, count = streaming.chunk_schedule(nbr, mask, block_n, n_chunks,
                                            block_m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i, *_: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # h_src stays in HBM
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, block_m, d), h_src.dtype),  # double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_stream_kernel, mean=mean, block_m=block_m),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(sched, count, nbr, mask, h_src)
    return out[:n]
