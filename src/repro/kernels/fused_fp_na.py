"""Pallas TPU kernel: fused Feature Projection + Neighbor Aggregation.

Paper guideline (b): "a subgraph-level kernel fusion technique can be used to
fuse the execution of feature projection and neighbor aggregation for each
subgraph".  On GPU (fuseGNN) this keeps projected features in shared memory;
the TPU adaptation exploits aggregator linearity — aggregate *raw* features
(memory-bound gather/reduce on the VPU) and project the aggregate (compute-
bound MXU matmul) inside one kernel, so the projected table never round-trips
HBM and the memory-bound and compute-bound phases share one VMEM residency
(the paper's "kernel mixing" realized as fusion).

Blocking: grid over row tiles; raw feature table [M, F] stays in VMEM (HGNN
raw dims up to ~5k×3066 ≈ 60 MB exceed VMEM for the largest inputs — the
wrapper in ops.py then tiles F with a second grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_ref, mask_ref, x_ref, w_ref, out_ref, *, mean: bool, nf_blocks: int):
    fi = pl.program_id(1)  # feature-dim tile index
    nbr = nbr_ref[...]
    mask = mask_ref[...]
    x = x_ref[...]  # [M, BF]
    w = w_ref[...]  # [BF, D]
    k = nbr.shape[1]
    acc = jnp.zeros((nbr.shape[0], x.shape[1]), jnp.float32)
    for j in range(k):
        rows = jnp.take(x, nbr[:, j], axis=0)
        acc = acc + rows.astype(jnp.float32) * mask[:, j][:, None].astype(jnp.float32)
    if mean:
        deg = jnp.maximum(mask.astype(jnp.float32).sum(axis=1, keepdims=True), 1.0)
        acc = acc / deg
    part = acc.astype(w.dtype) @ w  # MXU: fused projection of the aggregate
    # accumulate partial products across feature-dim tiles
    @pl.when(fi == 0)
    def _init():
        out_ref[...] = part.astype(out_ref.dtype)

    @pl.when(fi != 0)
    def _acc():
        out_ref[...] = (out_ref[...] + part).astype(out_ref.dtype)


def fused_fp_na(
    x_src: jax.Array,  # [M, F]
    w: jax.Array,  # [F, D]
    nbr: jax.Array,  # [N, K]
    mask: jax.Array,  # [N, K]
    mean: bool = True,
    block_n: int = 128,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    n, k = nbr.shape
    m, f = x_src.shape
    d = w.shape[1]
    n_pad = (-n) % block_n
    f_pad = (-f) % block_f
    if n_pad:
        nbr = jnp.pad(nbr, ((0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad), (0, 0)))
    if f_pad:
        x_src = jnp.pad(x_src, ((0, 0), (0, f_pad)))
        w = jnp.pad(w, ((0, f_pad), (0, 0)))
    nf_blocks = (f + f_pad) // block_f
    grid = ((n + n_pad) // block_n, nf_blocks)
    out = pl.pallas_call(
        functools.partial(_kernel, mean=mean, nf_blocks=nf_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i, fi: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, fi: (i, 0)),
            pl.BlockSpec((m, block_f), lambda i, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda i, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, fi: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), w.dtype),
        interpret=interpret,
    )(nbr, mask, x_src, w)
    return out[:n]
