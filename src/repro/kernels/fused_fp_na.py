"""Pallas TPU kernel: fused Feature Projection + Neighbor Aggregation.

Paper guideline (b): "a subgraph-level kernel fusion technique can be used to
fuse the execution of feature projection and neighbor aggregation for each
subgraph".  On GPU (fuseGNN) this keeps projected features in shared memory;
the TPU adaptation exploits aggregator linearity — aggregate *raw* features
(memory-bound gather/reduce on the VPU) and project the aggregate (compute-
bound MXU matmul) inside one kernel, so the projected table never round-trips
HBM and the memory-bound and compute-bound phases share one VMEM residency
(the paper's "kernel mixing" realized as fusion).

Blocking: grid over (row tile, feature tile).  Raw HGNN tables run big
(~5k x 3066 ~ 60 MB > VMEM), so the raw table has two paths like the other
NA kernels: **resident** (per-F-tile ``[M, BF]`` column slabs via BlockSpec)
when a slab fits VMEM, and **streaming** (table in HBM, scalar-prefetched
chunk schedule + double-buffered DMA of ``[BM, BF]`` sub-blocks) when it
does not — see ``kernels/streaming.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import streaming
from repro.kernels.segment_spmm import _accumulate, _mean


def _write_partial(out_ref, part, fi):
    # accumulate partial products across feature-dim tiles
    @pl.when(fi == 0)
    def _init():
        out_ref[...] = part.astype(out_ref.dtype)

    @pl.when(fi != 0)
    def _acc():
        out_ref[...] = (out_ref[...] + part).astype(out_ref.dtype)


def _kernel(nbr_ref, mask_ref, x_ref, w_ref, out_ref, *, mean: bool):
    fi = pl.program_id(1)  # feature-dim tile index
    nbr = nbr_ref[...]
    mask = mask_ref[...]
    w = w_ref[...]  # [BF, D]
    acc = jnp.zeros((nbr.shape[0], x_ref.shape[1]), jnp.float32)
    acc = _mean(_accumulate(acc, nbr, mask, x_ref[...], 0), mask, mean)
    part = acc.astype(w.dtype) @ w  # MXU: fused projection of the aggregate
    _write_partial(out_ref, part, fi)


def _stream_kernel(sched_ref, count_ref, nbr_ref, mask_ref, x_ref, w_ref,
                   out_ref, buf, sem, *, mean: bool, block_m: int,
                   block_f: int):
    t, fi = pl.program_id(0), pl.program_id(1)
    nc = count_ref[t]
    nbr = nbr_ref[...]
    mask = mask_ref[...]

    def get_dma(slot, s):
        c = sched_ref[t, s]
        return pltpu.make_async_copy(
            x_ref.at[pl.ds(c * block_m, block_m),
                     pl.ds(fi * block_f, block_f)],
            buf.at[slot], sem.at[slot])

    @pl.when(nc > 0)
    def _warmup():
        get_dma(0, 0).start()

    def body(s, acc):
        slot = jax.lax.rem(s, 2)

        @pl.when(s + 1 < nc)  # double buffer: next chunk in flight
        def _():
            get_dma(jax.lax.rem(s + 1, 2), s + 1).start()

        get_dma(slot, s).wait()
        lo = sched_ref[t, s] * block_m
        return _accumulate(acc, nbr, mask, buf[slot], lo)

    acc0 = jnp.zeros((nbr.shape[0], block_f), jnp.float32)
    acc = _mean(jax.lax.fori_loop(0, nc, body, acc0), mask, mean)
    w = w_ref[...]
    part = acc.astype(w.dtype) @ w
    _write_partial(out_ref, part, fi)


def fused_fp_na(
    x_src: jax.Array,  # [M, F]
    w: jax.Array,  # [F, D]
    nbr: jax.Array,  # [N, K]
    mask: jax.Array,  # [N, K]
    mean: bool = True,
    block_n: int = 128,
    block_f: int = 512,
    block_m: int = 0,  # 0 = auto (resident if an [M, BF] slab fits, else 512)
    vmem_budget: int = streaming.VMEM_TABLE_BUDGET,
    interpret: bool = False,
) -> jax.Array:
    n, k = nbr.shape
    m, f = x_src.shape
    d = w.shape[1]
    n_pad = (-n) % block_n
    f_pad = (-f) % block_f
    if n_pad:
        nbr = jnp.pad(nbr, ((0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad), (0, 0)))
    if f_pad:
        x_src = jnp.pad(x_src, ((0, 0), (0, f_pad)))
        w = jnp.pad(w, ((0, f_pad), (0, 0)))
    nbr = nbr.astype(jnp.int32)
    nf_blocks = (f + f_pad) // block_f
    grid = ((n + n_pad) // block_n, nf_blocks)
    out_shape = jax.ShapeDtypeStruct((n + n_pad, d), w.dtype)

    resident = block_m == 0 and streaming.table_fits_vmem(
        m, block_f * x_src.dtype.itemsize, vmem_budget)
    if resident:
        out = pl.pallas_call(
            functools.partial(_kernel, mean=mean),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, k), lambda i, fi: (i, 0)),
                pl.BlockSpec((block_n, k), lambda i, fi: (i, 0)),
                pl.BlockSpec((m, block_f), lambda i, fi: (0, fi)),
                pl.BlockSpec((block_f, d), lambda i, fi: (fi, 0)),
            ],
            out_specs=pl.BlockSpec((block_n, d), lambda i, fi: (i, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(nbr, mask, x_src, w)
        return out[:n]

    if block_m == 0:
        block_m = 512
    block_m = min(block_m, max(m, 1))
    x_src = streaming.pad_rows(x_src, block_m)
    n_chunks = x_src.shape[0] // block_m
    sched, count = streaming.chunk_schedule(nbr, mask, block_n, n_chunks,
                                            block_m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i, fi, *_: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, fi, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # raw table stays in HBM
            pl.BlockSpec((block_f, d), lambda i, fi, *_: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, fi, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, block_m, block_f), x_src.dtype),  # double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_stream_kernel, mean=mean, block_m=block_m,
                          block_f=block_f),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(sched, count, nbr, mask, x_src, w)
    return out[:n]
