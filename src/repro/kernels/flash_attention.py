"""Pallas TPU kernel: flash attention (GQA, causal, optional sliding window).

VMEM-tiled online-softmax attention for the LM substrate's prefill/train
path.  Grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is the
innermost ("arbitrary") dimension and accumulates into VMEM scratch
(m, l, acc), writing the output tile on the last kv step — the canonical
TPU flash structure.  GQA is folded into the BlockSpec index maps
(kv head = q head // group).

Block sizes default to (128, 512): q tile 128×Dh and kv tile 512×Dh keep the
working set (q + k + v + acc + scores ≈ 128·128·4·3 + 512·128·4·2 + 128·512·4
≈ 1 MB) well under the 16 MB v5e VMEM, and all matmul dims are 128-aligned
for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, causal: bool, window: int, scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip fully-masked tiles (causal: kv entirely in the future;
    # window: kv entirely out of the sliding window)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [BQ, Dh]
        k = k_ref[0, 0].astype(jnp.float32)  # [BK, Dh]
        v = v_ref[0, 0].astype(jnp.float32)  # [BK, Dh]
        s = q @ k.T  # [BQ, BK]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, rows >= cols)
        if window:
            mask = jnp.logical_and(mask, rows - cols < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]  # [BQ, 1]
        m_cur = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
        alpha = jnp.exp(m_prev - m_cur)  # [BQ, 1]
        p = jnp.exp(s - m_cur)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,  # [B, S, KVH, Dh]
    v: jax.Array,  # [B, S, KVH, Dh]
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    # layout: [B, H, S, Dh] tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, h, s // block_q, s // block_k)
    scale = 1.0 / (dh ** 0.5)
    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=block_q, block_k=block_k,
            causal=causal, window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
