"""Paper Fig. 6a — subgraph sparsity decreases as metapath length increases,
plus the guideline-(c) correlation model: a log-linear fit of density vs
length usable to pre-size sparsity-aware buffers (e.g. padded-degree caps)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.hgraph import metapath_adjacency, sparsity
from repro.data.synthetic import make_dblp, make_imdb

CASES = [
    ("imdb", ["M", "D", "M"]), ("imdb", ["M", "D", "M", "D", "M"]),
    ("imdb", ["M", "A", "M"]), ("imdb", ["M", "A", "M", "A", "M"]),
    ("dblp", ["A", "P", "A"]), ("dblp", ["A", "P", "T", "P", "A"]),
    ("dblp", ["A", "P", "V", "P", "A"]),
]


def run() -> list:
    rows: list = []
    graphs = {"imdb": make_imdb(), "dblp": make_dblp()}
    pts = []
    for ds, path in CASES:
        adj = metapath_adjacency(graphs[ds], path)
        s = sparsity(adj)
        length = len(path) - 1
        pts.append((length, max(1e-9, 1.0 - s)))
        rows.append((f"fig6a/{ds}/{''.join(p[0] for p in path)}", 0.0,
                     f"len={length} sparsity={s:.6f} nnz={adj.nnz}"))
    # guideline (c): correlation model  log10(density) ~ a*len + b
    lens = np.array([p[0] for p in pts], np.float64)
    dens = np.log10(np.array([p[1] for p in pts], np.float64))
    a, b = np.polyfit(lens, dens, 1)
    rows.append(("fig6a/correlation_model", 0.0,
                 f"log10_density={a:.3f}*len+{b:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
