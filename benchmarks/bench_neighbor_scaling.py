"""Paper Fig. 5a — Neighbor Aggregation time grows with the average number
of neighbors (edge-dropout sweep on the Reddit-like graph), HAN-GAT vs GCN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import stages
from repro.data.synthetic import make_reddit_like

SCALE = 0.02
DROPOUTS = (0.9, 0.75, 0.5, 0.25, 0.0)


def _edges(hg):
    a = hg.relations[("N", "nn", "N")]
    seg = np.repeat(np.arange(a.shape[0], dtype=np.int32), np.diff(a.indptr))
    return seg, a.indices.astype(np.int32)


def run() -> list:
    rows: list = []
    hg = make_reddit_like(scale=SCALE)
    n = hg.node_counts["N"]
    seg, idx = _edges(hg)
    rng = np.random.default_rng(0)
    d, heads = 64, 8
    h = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.1)
    hh = h.reshape(n, heads, d // heads)
    gat_p = stages.init_gat(jax.random.key(0), heads, d // heads)

    for rate in DROPOUTS:
        keep = rng.random(len(seg)) >= rate
        s = jnp.asarray(seg[keep])
        i = jnp.asarray(idx[keep])
        avg_deg = float(keep.sum()) / n
        gcn = jax.jit(lambda x, s=s, i=i: stages.mean_aggregate_csr(x, s, i, n))
        t_gcn = time_jitted(gcn, h)
        gat = jax.jit(lambda x, s=s, i=i: stages.gat_aggregate_csr(
            gat_p, x, x, s, i, n))
        t_gat = time_jitted(gat, hh)
        rows.append((f"fig5a/gcn/drop{rate}", t_gcn, f"avg_deg={avg_deg:.1f}"))
        rows.append((f"fig5a/han_gat/drop{rate}", t_gat, f"avg_deg={avg_deg:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
