"""Paper Fig. 5b — NA time grows with the NUMBER of metapaths (each metapath
adds one subgraph to aggregate). HAN on IMDB with 1..4 metapaths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import metapath as mp, stages
from repro.data.synthetic import make_imdb

METAPATHS = [["M", "D", "M"], ["M", "A", "M"],
             ["M", "D", "M", "D", "M"], ["M", "A", "M", "A", "M"]]


def run() -> list:
    rows: list = []
    hg = make_imdb()
    n = hg.node_counts["M"]
    heads, dh = 8, 8
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((n, heads, dh)).astype(np.float32) * 0.1)
    edges = []
    for p in METAPATHS:
        csr = mp.build_csr(hg, p)
        seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
        edges.append((jnp.asarray(seg), jnp.asarray(idx)))
    gat_p = stages.init_gat(jax.random.key(0), heads, dh)

    for k in range(1, len(METAPATHS) + 1):
        sub = edges[:k]

        def na(x):
            outs = [stages.gat_aggregate_csr(gat_p, x, x, s, i, n)
                    for s, i in sub]
            return jnp.stack(outs)

        t = time_jitted(jax.jit(na), h)
        rows.append((f"fig5b/han_NA/{k}_metapaths", t,
                     f"edges={sum(len(s) for s, _ in sub)}"))
    return rows


if __name__ == "__main__":
    emit(run())
