"""Serving resilience under a seeded chaos schedule (repro.serve.faults).

Per case (model/dataset) the slot engine serves a fixed deterministic
request queue while a seeded :class:`FaultInjector` drives the full
resilience surface: transient + persistent sampler exceptions, one forward
exception, an injected-latency burst that breaches the SLO (degradation
runs on ``slo_signal="injected"`` so the pressure trajectory — and every
degrade/recover counter — is host-independent), and a bounded queue that
sheds the overflow.  A second, partitioned case loses a partition mid-serve
and records whether the failover output stayed bit-exact vs a never-failed
run.

Rows record the mean per-step wall (us, recorded for the handbook but NOT
gated) plus the deterministic resilience counters ``run.py --check`` gates
EXACTLY: same seed, same queue, same schedule, same counters — any drift is
a behavior change in the recovery path, not noise.

Rows fold into ``BENCH_hgnn.json`` under ``resilience``.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit
from repro.configs.base import HGNNConfig
from repro.core.characterize import resilience_record
from repro.core.models import get_model
from repro.data.synthetic import make_dataset
from repro.serve.engine import HGNNRequest, HGNNServeEngine
from repro.serve.faults import Fault, FaultInjector
from repro.serve.resilience import ResilienceConfig
from repro.serve.sampler import HGNNSampler

CASES = [("han", "imdb"), ("rgcn", "imdb")]
N_REQUESTS = 32
FANOUT = 8
FAILOVER_CASES = [("han", "imdb")]
if os.environ.get("BENCH_SMOKE"):  # CI smoke: one chaos case + the failover
    CASES = [("han", "imdb")]


def _build(model: str, ds: str, partitions: int = 0):
    import jax

    hg = make_dataset(ds)
    cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                     n_classes=8, max_degree=32, fused=True, fanout=FANOUT,
                     partitions=partitions)
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(0), batch)
    fn = jax.jit(m.executor.forward)
    sampler = HGNNSampler(m.plan(), cfg, hg)
    n_t = hg.node_counts[m.plan().target]
    return m, params, fn, sampler, n_t


def _requests(n_t: int) -> list:
    # draw from a small id pool so duplicate target ids occur and the
    # admission dedup counter exercises deterministically
    rng = np.random.default_rng(0)
    pool = min(n_t, 48)
    return [HGNNRequest(targets=rng.integers(
        0, pool, size=int(rng.integers(1, 9)))) for _ in range(N_REQUESTS)]


def _counters(st: dict) -> str:
    rec = resilience_record(st)
    keys = ("ok_requests", "partial_requests", "failed_requests", "rejected",
            "shed", "deduped_rows", "retries", "failed_steps",
            "deadline_expired", "degrade_transitions", "recover_transitions",
            "max_degrade_level", "partition_failovers")
    kv = " ".join(f"{k}={rec[k]}" for k in keys)
    return (f"requests={N_REQUESTS} steps={rec['steps']} "
            f"recompiles={rec['recompiles']} {kv}")


def run() -> list:
    rows: list = []
    for model, ds in CASES:
        m, params, fn, sampler, n_t = _build(model, ds)
        inj = FaultInjector.seeded(0, n_steps=16, sampler=2, forward=1,
                                   persistent_sampler=1, latency_steps=4,
                                   latency_s=0.2)
        res = ResilienceConfig(max_queue=24, deadline_ms=60_000.0,
                               slo_ms=50.0, slo_signal="injected",
                               degrade_patience=1, recover_patience=2)
        eng = HGNNServeEngine(m.executor, params, sampler, slots=4,
                              slot_targets=2, fn=fn, resilience_cfg=res,
                              injector=inj)
        eng.warmup()
        eng.serve(_requests(n_t))
        st = eng.stats()
        rows.append((f"resilience/{model}/{ds}/chaos",
                     st["wall_mean_ms"] * 1e3, _counters(st)))
    for model, ds in FAILOVER_CASES:
        # partitioned arm: lose partition 0 at step 3, serve to completion,
        # and verify per-request logits vs a never-failed partitioned run
        outs = []
        for inj in (FaultInjector([Fault(step=3, kind="partition",
                                         partition=0)]), None):
            m, params, fn, sampler, n_t = _build(model, ds, partitions=3)
            eng = HGNNServeEngine(m.executor, params, sampler, slots=8,
                                  slot_targets=4, fn=fn, injector=inj)
            eng.warmup()
            reqs = _requests(n_t)
            eng.serve(reqs)
            outs.append((eng, eng.stats(), reqs))
        eng, st, reqs = outs[0]
        bitexact = int(all(
            np.array_equal(a.logits, b.logits)
            for a, b in zip(reqs, outs[1][2])))
        rs = st["resilience"]
        rows.append((
            f"resilience/{model}/{ds}/failover",
            st["wall_mean_ms"] * 1e3,
            f"requests={N_REQUESTS} steps={st['steps']} "
            f"ok_requests={rs['ok_requests']} "
            f"partition_failovers={rs['partition_failovers']} "
            f"surviving_k={eng._serve_plan.partition.k} "
            f"bitexact={bitexact}"))
    return rows


if __name__ == "__main__":
    emit(run())
