"""Paper Fig. 5c — inter-subgraph parallelism in NA + the NA->SA barrier.

Baseline: per-subgraph sequential kernels (DGL timeline). Optimized
(guideline §5): stacked [P,N,K] subgraphs aggregated by ONE vmapped kernel —
the inter-subgraph parallelism the paper identifies. Also measures the
barrier: SA cannot start until ALL subgraph NAs finish (it consumes the full
stack for the semantic-attention softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jitted
from benchmarks.hgnn_setup import build, stage_fns


def run() -> list:
    rows: list = []
    for ds in ("imdb", "acm"):
        # baseline: sequential per-subgraph CSR NA
        cfg_b, m_b, p_b, b_b = build("han", ds, fused=False)
        fns_b = stage_fns(m_b, p_b, b_b)
        t_seq = time_jitted(*fns_b["NA"][:1], *fns_b["NA"][1])
        # optimized: stacked padded subgraphs, vmap over the metapath dim
        cfg_f, m_f, p_f, b_f = build("han", ds, fused=True)
        fns_f = stage_fns(m_f, p_f, b_f)
        t_par = time_jitted(*fns_f["NA"][:1], *fns_f["NA"][1])
        rows.append((f"fig5c/{ds}/NA_sequential", t_seq, "baseline"))
        rows.append((f"fig5c/{ds}/NA_stacked_vmap", t_par,
                     f"speedup={t_seq / max(t_par, 1e-9):.2f}x"))
        # barrier evidence: SA input is the full [P,N,D] stack
        t_sa = time_jitted(*fns_f["SA"][:1], *fns_f["SA"][1])
        rows.append((f"fig5c/{ds}/SA_after_barrier", t_sa,
                     "consumes_all_subgraphs"))
    return rows


if __name__ == "__main__":
    emit(run())
