"""Paper Fig. 3 — breakdown on kernel TYPES (DM/TB/EW/DR) per stage.

Adaptation (DESIGN.md §2): no per-CUDA-kernel timeline exists on TPU; the
per-class shares come from the compiled HLO via the characterizer —
roofline-predicted time per class (max of compute/memory term using each
class's own FLOPs/bytes).

Paper claims to validate: FP is DM-dominated; NA is TB+EW dominated;
SA mixes DM + EW + DR.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, emit
from benchmarks.hgnn_setup import build, stage_fns
from repro.core.characterize import HBM_BW, PEAK_FLOPS, analyze_hlo_text

CASES = [("han", "imdb"), ("han", "dblp"), ("rgcn", "imdb"), ("magnn", "imdb")]
CLASSES = ("DM", "TB", "EW", "DR")


def class_times(rep):
    out = {}
    for c in CLASSES:
        fl = rep["flops_by_class"].get(c, 0.0)
        by = rep["hbm_bytes_by_class"].get(c, 0.0)
        out[c] = max(fl / PEAK_FLOPS, by / HBM_BW)
    return out


def run() -> list:
    rows: list = []
    for model, ds in CASES:
        cfg, m, params, batch = build(model, ds)
        fns = stage_fns(m, params, batch)
        for stage in ("FP", "NA", "SA"):
            fn, args = fns[stage]
            comp = fn.lower(*args).compile()
            rep = analyze_hlo_text(comp.as_text())
            ct = class_times(rep)
            tot = sum(ct.values()) or 1.0
            shares = " ".join(f"{c}={100*ct[c]/tot:.0f}%" for c in CLASSES)
            rows.append((f"fig3/{model}/{ds}/{stage}", tot * 1e6, shares))
    return rows


if __name__ == "__main__":
    emit(run())
