"""Shared HGNN benchmark setup: build (model, params, batch, staged fns)."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax

from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import make_dataset

_CACHE: Dict[Tuple, Tuple] = {}


def build(model: str, dataset: str, fused: bool = False, hidden: int = 64,
          max_degree: int = 32, max_instances: int = 8, seed: int = 0):
    key = (model, dataset, fused, hidden, max_degree, max_instances)
    if key in _CACHE:
        return _CACHE[key]
    cfg = HGNNConfig(model=model, dataset=dataset, hidden=hidden, n_heads=8,
                     n_classes=8, max_degree=max_degree,
                     max_instances=max_instances, fused=fused, seed=seed)
    hg = make_dataset(dataset)
    m = get_model(cfg)
    batch = m.prepare(hg)
    params = m.init(jax.random.key(seed), batch)
    _CACHE[key] = (cfg, m, params, batch)
    return _CACHE[key]


def stage_fns(m, params, batch):
    """Jitted per-stage callables chained on concrete intermediates.

    Delegates to the stage-graph executor (core/pipeline.py) so benchmarks
    measure the exact code path that serves traffic; the separate jit per
    stage mirrors DGL's separate kernel launches and exposes the NA->SA
    barrier (paper Fig. 5c)."""
    return m.executor.stage_fns(params, batch)
