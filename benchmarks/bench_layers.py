"""Depth sweep: how the stage mix scales when HGNN layers stack.

The follow-up characterization ("Characterizing and Understanding HGNN
Training on GPUs", arXiv:2407.11790) shows the NA/SA share and memory
traffic shift with model depth; this module records that story for this
repro's L-layer execution (`HGNNConfig.layers`):

* per-layer stage walls (`L{i}.FP/NA/SA`, plain FP/NA/SA at L=1) with the
  layer's NA share derived at render time;
* per-layer characterization records (FLOPs / HBM bytes from the compiled
  stage HLO — deterministic, so `run.py --check` gates them);
* the partitioned arm's halo traffic: the halo maps are graph-invariant,
  so an L-layer stack re-exchanges the updated features every layer and
  total traffic is halo-bytes × L (`layers/<case>/halo` rows, K=4).

Rows fold into ``BENCH_hgnn.json`` under ``layers``.
"""
from __future__ import annotations

import os

import jax

from benchmarks.common import emit, time_jitted
from repro.configs.base import HGNNConfig
from repro.core.characterize import analyze_hlo_text, partition_traffic
from repro.core.models import get_model
from repro.data.synthetic import make_dataset

CASES = [("han", "imdb"), ("rgcn", "imdb")]
DEPTHS = (1, 2, 3)
HALO_K = 4
if os.environ.get("BENCH_SMOKE"):  # CI smoke: cheapest case under a timeout
    CASES = [("rgcn", "imdb")]
    DEPTHS = (1, 2)


def run() -> list:
    rows: list = []
    for model, ds in CASES:
        hg = make_dataset(ds)
        # the partitioner output is depth-invariant up to its single-vs-
        # multi-layer variant (RGCN relabels every relation when L > 1), so
        # the pure-Python edge-cut prepare runs once per variant, not per L
        part_cache: dict = {}
        for depth in DEPTHS:
            cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                             n_classes=8, max_degree=32, fused=True,
                             layers=depth)
            m = get_model(cfg)
            batch = m.prepare(hg)
            params = m.init(jax.random.key(0), batch)
            fns = m.executor.stage_fns(params, batch)
            stage_names = [n for n in fns if n != "head"]
            times = {n: time_jitted(fn, *args)
                     for n, (fn, args) in fns.items() if n != "head"}
            for n in stage_names:
                rows.append((f"layers/{model}/{ds}/L{depth}/{n}",
                             times[n], ""))
            # characterization AFTER the walls so compiles never skew them
            for n in stage_names:
                fn, args = fns[n]
                rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
                rows.append((f"layers/{model}/{ds}/L{depth}/char/{n}", 0.0,
                             f"flops={rep['total_flops']:.6g} "
                             f"hbm_bytes={rep['total_hbm_bytes']:.6g}"))
            # partitioned arm: per-layer halo re-exchange -> traffic x L.
            # Only layer-0 FP runs here — it yields the per-type feature
            # shards whose widths price a halo row, and every layer's
            # exchange moves the same hidden-width tables over the same
            # graph-invariant maps, so the depth just multiplies.
            # only RGCN's padded relational layout has a distinct multi-
            # layer partitioner; HAN's stacked tables are depth-invariant
            variant = model == "rgcn" and depth > 1
            if variant not in part_cache:
                cfg_p = cfg.replace(partitions=HALO_K)
                m_p = get_model(cfg_p)
                batch_p = m_p.prepare(hg)
                params_p = m_p.init(jax.random.key(0), batch_p)
                part_cache[variant] = (batch_p["part"],
                                       m_p.fp(params_p, batch_p))
            part, h_own = part_cache[variant]
            traffic = partition_traffic(part, h_own, layers=depth)
            rows.append((
                f"layers/{model}/{ds}/L{depth}/halo", 0.0,
                f"k={HALO_K} layers={traffic['layers']} "
                f"halo_bytes={traffic['halo_bytes']:.0f} "
                f"halo_bytes_total={traffic['halo_bytes_total']:.0f} "
                f"cut_edges={traffic['cut_edges']}"))
    return rows


if __name__ == "__main__":
    emit(run())
