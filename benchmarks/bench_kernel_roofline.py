"""Paper Fig. 4 + Table 3 — per-stage arithmetic intensity and percent of
peak on the roofline (HAN on DBLP, the paper's featured example).

Paper reference points (T4): FP/sgemm AI=26.8 FLOP/B (compute-bound,
ridge=9.37); NA/SpMMCsr AI=0.49 (3.9% peak); SA uEleWise AI=0.1, Reduce 0.34.
v5e ridge = 197e12/819e9 = 240 FLOP/B — all graph stages stay memory-bound
on TPU, only FP approaches the ridge.
"""
from __future__ import annotations

from benchmarks.common import emit
from benchmarks.hgnn_setup import build, stage_fns
from repro.core.characterize import HBM_BW, PEAK_FLOPS, analyze_hlo_text

RIDGE = PEAK_FLOPS / HBM_BW


def run() -> list:
    rows: list = []
    cfg, m, params, batch = build("han", "dblp")
    fns = stage_fns(m, params, batch)
    for stage in ("FP", "NA", "SA"):
        fn, args = fns[stage]
        rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
        fl, by = rep["total_flops"], max(rep["total_hbm_bytes"], 1.0)
        ai = fl / by
        # achievable fraction of peak at this AI on the v5e roofline
        frac = min(1.0, ai / RIDGE)
        t_est = max(fl / PEAK_FLOPS, by / HBM_BW)
        rows.append((f"fig4/han/dblp/{stage}", t_est * 1e6,
                     f"AI={ai:.2f}FLOP/B peak={100*frac:.1f}% "
                     f"bound={'compute' if ai > RIDGE else 'memory'}"))
    rows.append(("fig4/ridge", 0.0, f"v5e_ridge={RIDGE:.0f}FLOP/B_paper_T4=9.37"))
    return rows


if __name__ == "__main__":
    emit(run())
