"""Graph-partitioned execution: cut-ratio vs halo-traffic vs NA time.

Sweeps the partition count K for HAN (stacked metapath layout) and RGCN
(padded per-relation layout) on IMDB and records, per K:

* the partitioner's quality — ``cut_ratio`` (cut edges / total edges) and the
  halo volume the cut induces (``halo_rows`` / ``halo_bytes``, priced at the
  projected-feature width that actually crosses partitions);
* the cost of the new communication stage — ``gather_halo`` wall time;
* what partitioning does to the dominant stage — per-partition NA wall time.

K=1 is the degenerate baseline (empty halos, zero cut) so the sweep shows the
traffic growing with K.  Rows fold into ``BENCH_hgnn.json`` under
``partition`` (the snapshot ``benchmarks/run.py --check`` gates against).
"""
from __future__ import annotations

import os

import jax

from benchmarks.common import emit, time_jitted
from repro.configs.base import HGNNConfig
from repro.core.characterize import partition_traffic
from repro.core.models import get_model
from repro.data.synthetic import make_dataset

CASES = [("han", "imdb"), ("rgcn", "imdb")]
KS = (1, 2, 4)
if os.environ.get("BENCH_SMOKE"):  # CI smoke: cheapest case under a timeout
    CASES = [("rgcn", "imdb")]
    KS = (1, 4)


def run() -> list:
    rows: list = []
    for model, ds in CASES:
        hg = make_dataset(ds)
        for k in KS:
            cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                             n_classes=8, max_degree=32, fused=True,
                             partitions=k)
            m = get_model(cfg)
            batch = m.prepare(hg)
            params = m.init(jax.random.key(0), batch)
            fns = m.executor.stage_fns(params, batch)
            na_fn, na_args = fns["NA"]
            na_us = time_jitted(na_fn, *na_args)
            if "gather_halo" in fns:
                gh_fn, gh_args = fns["gather_halo"]
                halo_us = time_jitted(gh_fn, *gh_args)
                traffic = partition_traffic(batch["part"], gh_args[0])
            else:
                halo_us = 0.0
                traffic = {"halo_rows": 0.0, "halo_bytes": 0.0,
                           "cut_edges": 0, "edges_total": 0, "cut_ratio": 0.0}
            rows.append((
                f"partition/{model}/{ds}/k{k}/NA", na_us,
                f"cut_ratio={traffic['cut_ratio']:.4f} "
                f"cut_edges={traffic['cut_edges']} "
                f"halo_rows={traffic['halo_rows']:.0f} "
                f"halo_bytes={traffic['halo_bytes']:.0f}"))
            rows.append((
                f"partition/{model}/{ds}/k{k}/gather_halo", halo_us,
                f"halo_bytes={traffic['halo_bytes']:.0f}"))
    return rows


if __name__ == "__main__":
    emit(run())
