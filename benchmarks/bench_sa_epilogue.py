"""Fused NA→SA epilogue — one fewer full ``[P, N, D]`` HBM pass in SA.

Two-pass SA (kernels/semantic_attn.py) reads the NA output stack twice:
pass 1 computes the semantic scores ``w_p = mean_n q·tanh(z_p W + b)``,
pass 2 the weighted combine.  With the epilogue fused into the NA kernel
(kernels/gat_na.py ``sem=...``) the scores accumulate while each ``z`` tile
is still in VMEM, so the SA stage that remains is a length-P softmax plus
the combine — exactly one read of the stack.

Bytes are accounted with ``core/characterize.py`` on the lowered SA stage
functions (fusion-boundary HBM bytes), which is what ``BENCH_hgnn.json``
records as the ``z_passes_saved`` snapshot; the in-kernel epilogue itself is
parity-checked against ``ref.gat_na_fused_sa`` in interpret mode.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from benchmarks.hgnn_setup import build
from repro.core import semantics
from repro.core.characterize import analyze_hlo_text
from repro.kernels import ref
from repro.kernels.gat_na import gat_na


def run() -> list:
    rows: list = []
    cfg, m, params, batch = build("han", "imdb", fused=True)
    h = m.fp(params, batch)
    z = m.na(params, batch, h)  # [P, N, D] NA output stack
    p_sem = params["sem"]

    # SA as served without the epilogue: both passes read z
    two_pass = jax.jit(semantics.semantic_attention)
    # SA remainder with the epilogue: scores already left the NA kernel
    fused_rest = jax.jit(
        lambda zz, wp: jnp.einsum("p,pnd->nd", jax.nn.softmax(wp), zz))
    wp = jnp.einsum("pnh,h->pn", jnp.tanh(z @ p_sem["W"] + p_sem["b"]),
                    p_sem["q"]).mean(axis=1)
    out2 = two_pass(p_sem, z)
    out1 = fused_rest(z, wp)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)

    rep2 = analyze_hlo_text(two_pass.lower(p_sem, z).compile().as_text())
    rep1 = analyze_hlo_text(fused_rest.lower(z, wp).compile().as_text())
    z_bytes = z.size * z.dtype.itemsize
    saved = rep2["total_hbm_bytes"] - rep1["total_hbm_bytes"]
    passes_saved = saved / z_bytes
    # same threshold as the CI artifact assert (>= 1 full pass saved)
    assert passes_saved >= 1.0, (rep2["total_hbm_bytes"],
                                 rep1["total_hbm_bytes"], z_bytes)

    t2 = time_jitted(two_pass, p_sem, z)
    t1 = time_jitted(fused_rest, z, wp)
    rows.append(("sa_epilogue/two_pass", t2,
                 f"hbm_bytes={rep2['total_hbm_bytes']:.0f} z_bytes={z_bytes} "
                 f"z_passes={rep2['total_hbm_bytes'] / z_bytes:.2f}"))
    rows.append(("sa_epilogue/fused", t1,
                 f"hbm_bytes={rep1['total_hbm_bytes']:.0f} "
                 f"z_passes={rep1['total_hbm_bytes'] / z_bytes:.2f} "
                 f"z_passes_saved={passes_saved:.2f}"))

    # in-kernel epilogue parity (interpret mode) on a row slice — CI guard
    sl = 128 if os.environ.get("BENCH_SMOKE") else 512
    zk, wk = gat_na(params["gat"], h[:sl], h, batch["nbr"][:, :sl],
                    batch["mask"][:, :sl], block_n=64, interpret=True,
                    sem=p_sem)
    zr, wr = ref.gat_na_fused_sa(params["gat"], h[:sl], h,
                                 batch["nbr"][:, :sl], batch["mask"][:, :sl],
                                 p_sem["W"], p_sem["b"], p_sem["q"])
    err = max(float(jnp.abs(zk - zr).max()), float(jnp.abs(wk - wr).max()))
    assert err < 1e-4, err
    rows.append(("sa_epilogue/kernel_interpret_parity", 0.0,
                 f"max_abs_err={err:.2e}"))
    return rows


if __name__ == "__main__":
    emit(run())
