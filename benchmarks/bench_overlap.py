"""Async stage-graph overlap: critical-path vs serial-sum accounting.

The paper's stage taxonomy serializes FP -> NA -> SA per layer; the
`ScheduleSpec` schedule relaxes that to the plan-derived dependency DAG
(`StageGraphExecutor.schedule_edges`): the partitioned arm's halo exchange
runs concurrently with NA over owned rows, and the bucketed / instance NA
layouts dispatch one NA stage per metapath with a single join at SA.  This
module records, per case:

* the deterministic DAG counters (`.../dag`: stages, edges, concurrent
  pairs) and the bit-exactness flag (`.../parity`) — plan-derived output,
  gated by ``run.py --check`` at EXACT equality;
* the measured per-stage walls folded through
  ``characterize.overlap_accounting`` (`.../accounting`): serial-sum (the
  blocking schedule) vs critical-path (the overlapped schedule) plus the
  saving — walls, recorded but never gated;
* per-stage exposure rows (`.../exposure/<stage>`): how much of the
  critical path each stage is responsible for — a fully-hidden halo
  exchange exposes ~0 even with a large wall.

Rows fold into ``BENCH_hgnn.json`` under ``overlap``.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.configs.base import HGNNConfig
from repro.core.characterize import overlap_accounting
from repro.core.models import get_model
from repro.data.synthetic import make_dataset

# (model, dataset, case label, config overrides) — one case per overlap
# source: per-metapath NA concurrency (bucketed HAN, MAGNN instances) and
# the partitioned halo/compute split (multi-layer so the exchange repeats)
CASES = [
    ("han", "imdb", "bucketed", dict(degree_buckets=3)),
    ("magnn", "imdb", "base", dict()),
    ("han", "imdb", "k4L2", dict(partitions=4, layers=2)),
    ("rgcn", "imdb", "k4L2", dict(partitions=4, layers=2)),
]
if os.environ.get("BENCH_SMOKE"):  # CI smoke: one case per overlap source
    CASES = [
        ("han", "imdb", "bucketed", dict(degree_buckets=3)),
        ("rgcn", "imdb", "k4L2", dict(partitions=4, layers=2)),
    ]


def run() -> list:
    rows: list = []
    for model, ds, case, kw in CASES:
        hg = make_dataset(ds)
        cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                         n_classes=8, max_degree=32, fused=True, overlap=2,
                         **kw)
        m = get_model(cfg)
        batch = m.prepare(hg)
        params = m.init(jax.random.key(0), batch)
        ex = m.executor
        base = f"overlap/{model}/{ds}/{case}"
        rec = ex.overlap_record()
        rows.append((base + "/dag", 0.0,
                     f"depth={rec['depth']} stages={rec['stages']} "
                     f"edges={rec['edges']} "
                     f"concurrent_pairs={rec['concurrent_pairs']} "
                     f"overlapped_stages={rec['overlapped_stages']}"))
        # the overlapped dispatch must be BIT-EXACT the serial forward
        ref = np.asarray(jax.jit(m.forward)(params, batch))
        out = np.asarray(ex.forward_overlapped(params, batch))
        rows.append((base + "/parity", 0.0,
                     f"bitexact={int(np.array_equal(ref, out))}"))
        # per-stage walls at the schedule's dispatch granularity -> the
        # DAG's critical path vs the blocking schedule's serial sum
        fns = ex.overlap_stage_fns(params, batch)
        walls = {n: time_jitted(fn, *args) for n, (fn, args) in fns.items()}
        acct = overlap_accounting(ex.schedule_edges(), walls)
        rows.append((base + "/accounting", acct["critical_path_us"],
                     f"serial_sum_us={acct['serial_sum_us']:.1f} "
                     f"critical_path_us={acct['critical_path_us']:.1f} "
                     f"overlap_saved_us={acct['overlap_saved_us']:.1f}"))
        for n, v in acct["exposure_us"].items():
            rows.append((base + f"/exposure/{n}", v,
                         f"wall_us={walls[n]:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
