"""Paper Fig. 2 — execution-time breakdown across the FP/NA/SA stages for
RGCN / HAN / MAGNN on IMDB / ACM / DBLP (baseline, DGL-faithful path).

Paper claim to validate: Neighbor Aggregation dominates (74% on average);
FP 19%, SA 7%.
"""
from __future__ import annotations

import os

from benchmarks.common import Row, emit, time_jitted
from benchmarks.hgnn_setup import build, stage_fns

CASES = [
    ("rgcn", "imdb"), ("rgcn", "acm"), ("rgcn", "dblp"),
    ("han", "imdb"), ("han", "acm"), ("han", "dblp"),
    ("magnn", "imdb"), ("magnn", "acm"), ("magnn", "dblp"),
]
if os.environ.get("BENCH_SMOKE"):  # CI smoke: one small case under a timeout
    CASES = [("rgcn", "imdb")]


def run() -> list:
    rows: list = []
    na_shares = []
    for model, ds in CASES:
        kw = {}
        if model == "magnn":
            kw = dict(max_instances=8)
        cfg, m, params, batch = build(model, ds, **kw)
        fns = stage_fns(m, params, batch)
        times = {name: time_jitted(fn, *args) for name, (fn, args) in fns.items()}
        total = times["FP"] + times["NA"] + times["SA"]
        for stage in ("FP", "NA", "SA"):
            share = 100.0 * times[stage] / total
            rows.append((f"fig2/{model}/{ds}/{stage}", times[stage],
                         f"share={share:.1f}%"))
        na_shares.append(100.0 * times["NA"] / total)
    rows.append(("fig2/avg_NA_share", 0.0,
                 f"avg_na_share={sum(na_shares)/len(na_shares):.1f}%_paper=74%"))
    return rows


if __name__ == "__main__":
    emit(run())
