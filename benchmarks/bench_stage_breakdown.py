"""Paper Fig. 2 — execution-time breakdown across the FP/NA/SA stages for
RGCN / HAN / MAGNN on IMDB / ACM / DBLP (baseline, DGL-faithful path).

Paper claim to validate: Neighbor Aggregation dominates (74% on average);
FP 19%, SA 7%.

Alongside the wall-clock shares, each stage also gets a characterization
record (FLOPs / HBM bytes / roofline bound via ``core/characterize.py``)
from the stage-graph executor — the same plan/codepath that serves traffic
(``fig2/<model>/<ds>/<stage>/char`` rows, folded into ``BENCH_hgnn.json``).
"""
from __future__ import annotations

import os

from benchmarks.common import Row, emit, time_jitted
from benchmarks.hgnn_setup import build, stage_fns
from repro.core.characterize import analyze_hlo_text, roofline

CASES = [
    ("rgcn", "imdb"), ("rgcn", "acm"), ("rgcn", "dblp"),
    ("han", "imdb"), ("han", "acm"), ("han", "dblp"),
    ("magnn", "imdb"), ("magnn", "acm"), ("magnn", "dblp"),
]
if os.environ.get("BENCH_SMOKE"):  # CI smoke: one small case under a timeout
    CASES = [("rgcn", "imdb")]


def run() -> list:
    rows: list = []
    na_shares = []
    for model, ds in CASES:
        kw = {}
        if model == "magnn":
            kw = dict(max_instances=8)
        cfg, m, params, batch = build(model, ds, **kw)
        fns = stage_fns(m, params, batch)
        times = {name: time_jitted(fn, *args) for name, (fn, args) in fns.items()}
        total = times["FP"] + times["NA"] + times["SA"]
        for stage in ("FP", "NA", "SA"):
            share = 100.0 * times[stage] / total
            rows.append((f"fig2/{model}/{ds}/{stage}", times[stage],
                         f"share={share:.1f}%"))
        # per-stage characterization from the same executor stage fns —
        # after ALL wall timings so compile work never skews them
        for stage in ("FP", "NA", "SA"):
            fn, args = fns[stage]
            rep = analyze_hlo_text(fn.lower(*args).compile().as_text())
            bound = roofline(rep, 1, 0.0)["bound"]
            rows.append((f"fig2/{model}/{ds}/{stage}/char", 0.0,
                         f"flops={rep['total_flops']:.6g} "
                         f"hbm_bytes={rep['total_hbm_bytes']:.6g} "
                         f"bound={bound}"))
        na_shares.append(100.0 * times["NA"] / total)
    if not os.environ.get("BENCH_SMOKE"):
        # the average is only meaningful over the full 9-case matrix; a
        # smoke run must not overwrite the committed figure with one case
        rows.append(("fig2/avg_NA_share", 0.0,
                     f"avg_na_share={sum(na_shares)/len(na_shares):.1f}%_paper=74%"))
    return rows


if __name__ == "__main__":
    emit(run())
