"""The 40-cell LM roofline table (brief deliverable g): reads the dry-run
JSONs produced by repro.launch.dryrun and emits one CSV row per cell.
Derived column: the three terms + bound + mfu proxy."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def run() -> list:
    rows: list = []
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        rows.append(("lm_roofline/missing", 0.0,
                     f"run_python_-m_repro.launch.dryrun_first ({RESULTS})"))
        return rows
    for path in files:
        r = json.load(open(path))
        rl = r["roofline"]
        name = f"lm_roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        rows.append((name, rl["step_time_s"] * 1e6,
                     f"bound={rl['bound']} comp={rl['compute_s']:.3f}s "
                     f"mem={rl['memory_s']:.3f}s coll={rl['collective_s']:.3f}s "
                     f"mfu={rl['mfu_proxy']:.4f} "
                     f"peak_gib={r['memory']['peak_device_gib']}"))
    return rows


if __name__ == "__main__":
    emit(run())
