"""Benchmark driver: one module per paper table/figure + the LM roofline.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py contract).

When the HGNN trajectory modules run (``bench_stage_breakdown`` and/or
``bench_na_fused``), their rows are also folded into ``BENCH_hgnn.json`` at
the repo root — the machine-readable perf baseline future PRs diff against
(stage breakdown + fused-vs-baseline NA speedup + launch counts).
"""
import json
import re
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "bench_stage_breakdown",     # Fig. 2
    "bench_kernel_types",        # Fig. 3
    "bench_kernel_roofline",     # Fig. 4 + Table 3
    "bench_neighbor_scaling",    # Fig. 5a
    "bench_metapath_scaling",    # Fig. 5b
    "bench_subgraph_parallelism",  # Fig. 5c
    "bench_sparsity_vs_length",  # Fig. 6a + guideline (c)
    "bench_total_vs_metapaths",  # Fig. 6b
    "bench_fusion",              # guidelines §5 before/after
    "bench_na_fused",            # fused GAT-NA vs per-head baseline
    "bench_lm_roofline",         # 40-cell arch x shape roofline table
]

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_hgnn.json"


def write_bench_json(results: dict) -> None:
    """Fold HGNN trajectory rows into BENCH_hgnn.json.

    Merges into the existing file so running one module never clobbers the
    other module's committed section; only called when every selected
    module succeeded."""
    data: dict = {"schema": 1, "source": "benchmarks/run.py"}
    if BENCH_JSON.exists():
        try:
            data.update(json.loads(BENCH_JSON.read_text()))
        except json.JSONDecodeError:
            pass  # rewrite a corrupt baseline from scratch
    sb = results.get("bench_stage_breakdown")
    if sb:
        breakdown: dict = {}
        for name, us, derived in sb:
            m = re.fullmatch(r"fig2/(\w+)/(\w+)/(FP|NA|SA)", name)
            if m:
                breakdown.setdefault(f"{m.group(1)}/{m.group(2)}", {})[
                    m.group(3)] = round(us, 1)
            elif name == "fig2/avg_NA_share":
                m2 = re.search(r"avg_na_share=([\d.]+)", derived)
                if m2:
                    data["avg_na_share_pct"] = float(m2.group(1))
        # merge per case: a BENCH_SMOKE run (one case) must not shrink the
        # committed multi-case baseline
        data.setdefault("stage_breakdown_us", {}).update(breakdown)
    nf = results.get("bench_na_fused")
    if nf:
        fused: dict = {}
        for name, us, derived in nf:
            if name == "na_fused/csr_baseline":
                fused["baseline_csr_us"] = round(us, 1)
            elif name == "na_fused/padded_per_head":
                fused["per_head_us"] = round(us, 1)
                m = re.search(r"na_launches=(\d+)", derived)
                fused["na_launches_per_head"] = int(m.group(1)) if m else None
            elif name == "na_fused/fused_all_heads":
                fused["fused_us"] = round(us, 1)
                m = re.search(r"speedup_vs_csr=([\d.]+)x", derived)
                fused["speedup_vs_baseline"] = float(m.group(1)) if m else None
                fused["na_launches_fused"] = 1
            elif name == "na_fused/kernel_interpret_parity":
                m = re.search(r"max_abs_err=([\d.e+-]+)", derived)
                fused["kernel_max_abs_err"] = float(m.group(1)) if m else None
        data["na_fused"] = fused
    if sb or nf:
        BENCH_JSON.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {BENCH_JSON.name}", flush=True)


def main() -> None:
    import importlib

    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failures = 0
    results: dict = {}
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            from benchmarks.common import emit

            rows = mod.run()
            emit(rows)
            results[name] = rows
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED\n{traceback.format_exc()}", flush=True)
    if not failures:  # never record a partial/failed run as the baseline
        write_bench_json(results)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
