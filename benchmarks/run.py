"""Benchmark driver: one module per paper table/figure + the LM roofline.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py contract)."""
import sys
import time
import traceback

MODULES = [
    "bench_stage_breakdown",     # Fig. 2
    "bench_kernel_types",        # Fig. 3
    "bench_kernel_roofline",     # Fig. 4 + Table 3
    "bench_neighbor_scaling",    # Fig. 5a
    "bench_metapath_scaling",    # Fig. 5b
    "bench_subgraph_parallelism",  # Fig. 5c
    "bench_sparsity_vs_length",  # Fig. 6a + guideline (c)
    "bench_total_vs_metapaths",  # Fig. 6b
    "bench_fusion",              # guidelines §5 before/after
    "bench_lm_roofline",         # 40-cell arch x shape roofline table
]


def main() -> None:
    import importlib

    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            from benchmarks.common import emit

            emit(mod.run())
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED\n{traceback.format_exc()}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
