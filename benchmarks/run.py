"""Benchmark driver: one module per paper table/figure + the LM roofline.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py contract).

When the HGNN trajectory modules run (``bench_stage_breakdown``,
``bench_na_fused``, ``bench_sa_epilogue``, ``bench_partition``,
``bench_layers``, ``bench_serving`` and/or ``bench_overlap``), their rows
are also folded into
``BENCH_hgnn.json`` at the repo root — the machine-readable perf baseline
future PRs diff against (per-stage wall + characterization breakdown,
fused-vs-baseline and bucketed-vs-CSR NA speedups + launch counts, the
fused NA→SA epilogue's saved-HBM-pass snapshot, the partitioned
halo-traffic sweep, the L-layer depth sweep with per-layer stage records +
halo-bytes × L, the request-path serving sweep with its sampled frontier
traffic + ladder hit counts, and the seeded chaos sweep with its
retry/degrade/shed/failover counters).

``--check`` turns the run into a regression gate: before the new snapshot is
written, every fresh stage cost (FP/NA/SA and, for partitioned runs, the
halo-exchange stage and its cut/halo traffic) is diffed against the
committed ``BENCH_hgnn.json``; the run fails on a >20% regression (wall
times behind a small absolute floor, ``BENCH_GATE_FLOOR_US``, to absorb CI
timer noise) and fails loudly when a committed stage is missing from the
fresh run.
"""
import json
import os
import re
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "bench_stage_breakdown",     # Fig. 2
    "bench_kernel_types",        # Fig. 3
    "bench_kernel_roofline",     # Fig. 4 + Table 3
    "bench_neighbor_scaling",    # Fig. 5a
    "bench_metapath_scaling",    # Fig. 5b
    "bench_subgraph_parallelism",  # Fig. 5c
    "bench_sparsity_vs_length",  # Fig. 6a + guideline (c)
    "bench_total_vs_metapaths",  # Fig. 6b
    "bench_fusion",              # guidelines §5 before/after
    "bench_na_fused",            # fused GAT-NA vs per-head baseline
    "bench_sa_epilogue",         # fused NA->SA epilogue HBM-pass snapshot
    "bench_partition",           # partitioned execution: cut vs halo vs NA
    "bench_layers",              # L-layer depth sweep: stage mix + halo x L
    "bench_serving",             # request-path slot serving: sampled minibatch
    "bench_resilience",          # seeded chaos: retries/degrade/shed/failover
    "bench_residency",           # hot-row cache: hit-rate vs NA HBM bytes
    "bench_overlap",             # async stage DAG: critical-path vs serial
    "bench_lm_roofline",         # 40-cell arch x shape roofline table
]

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_hgnn.json"


def parse_breakdown(rows) -> dict:
    """``fig2/<model>/<ds>/<stage>`` wall rows -> {case: {stage: us}}."""
    out: dict = {}
    for name, us, derived in rows:
        m = re.fullmatch(r"fig2/(\w+)/(\w+)/(FP|NA|SA)", name)
        if m:
            out.setdefault(f"{m.group(1)}/{m.group(2)}", {})[
                m.group(3)] = round(us, 1)
    return out


def parse_characterization(rows) -> dict:
    """``fig2/<model>/<ds>/<stage>/char`` rows -> {case: {stage: metrics}}."""
    out: dict = {}
    for name, us, derived in rows:
        m = re.fullmatch(r"fig2/(\w+)/(\w+)/(FP|NA|SA)/char", name)
        if m:
            d = dict(kv.split("=", 1) for kv in derived.split())
            out.setdefault(f"{m.group(1)}/{m.group(2)}", {})[
                m.group(3)] = {"flops": float(d["flops"]),
                               "hbm_bytes": float(d["hbm_bytes"]),
                               "bound": d["bound"]}
    return out


def parse_partition(rows) -> dict:
    """``partition/<model>/<ds>/k<K>/<stage>`` rows ->
    {case/kK: {stage_us + cut/halo metrics}}."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"partition/(\w+)/(\w+)/k(\d+)/(NA|gather_halo)",
                         name)
        if not m:
            continue
        rec = out.setdefault(
            f"{m.group(1)}/{m.group(2)}/k{m.group(3)}", {})
        rec[f"{m.group(4)}_us"] = round(us, 1)
        d = dict(kv.split("=", 1) for kv in derived.split())
        for key in ("cut_ratio", "halo_rows", "halo_bytes"):
            if key in d:
                rec[key] = float(d[key])
        if "cut_edges" in d:
            rec["cut_edges"] = int(d["cut_edges"])
    return out


def parse_layers(rows) -> dict:
    """``layers/<model>/<ds>/L<depth>/...`` rows -> {case: record}.

    Per case (``han/imdb/L2``): ``stages_us`` per-layer stage walls,
    ``char`` per-layer FLOPs/HBM bytes (deterministic, gated), and ``halo``
    the partitioned arm's traffic (halo-bytes × L, deterministic, gated)."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"layers/(\w+)/(\w+)/L(\d+)/(.+)", name)
        if not m:
            continue
        case = f"{m.group(1)}/{m.group(2)}/L{m.group(3)}"
        rec = out.setdefault(case, {})
        tail = m.group(4)
        d = dict(kv.split("=", 1) for kv in derived.split()) if derived else {}
        if tail == "halo":
            rec["halo"] = {k: float(v) for k, v in d.items()}
        elif tail.startswith("char/"):
            rec.setdefault("char", {})[tail[5:]] = {
                "flops": float(d["flops"]),
                "hbm_bytes": float(d["hbm_bytes"])}
        else:
            rec.setdefault("stages_us", {})[tail] = round(us, 1)
    return out


def parse_serving(rows) -> dict:
    """``serving/<model>/<ds>/s<slots>`` rows -> {case: record}.

    ``step_us`` is the latency wall (recorded, never gated); the rest are
    deterministic serving quantities — frontier bytes, ladder hit counts,
    step/recompile counts — that ``--check`` gates."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"serving/(\w+)/(\w+)/s(\d+)", name)
        if not m:
            continue
        d = dict(kv.split("=", 1) for kv in derived.split())
        out[f"{m.group(1)}/{m.group(2)}/s{m.group(3)}"] = {
            "step_us": round(us, 1),
            "requests": int(d["requests"]),
            "targets": int(d["targets"]),
            "steps": int(d["steps"]),
            "recompiles": int(d["recompiles"]),
            "frontier_bytes": float(d["frontier_bytes"]),
            "truncated": int(d["truncated"]),
            "rung_hits": {int(kv.split(":")[0]): int(kv.split(":")[1])
                          for kv in d["rung_hits"].split(";") if kv},
            "throughput_tps": float(d["throughput_tps"]),
        }
    return out


def parse_resilience(rows) -> dict:
    """``resilience/<model>/<ds>/<scenario>`` rows -> {case: record}.

    ``step_us`` is the latency wall (recorded, never gated); every other
    field is a deterministic counter from a seeded fault schedule — the
    gate compares them EXACTLY (same seed + same queue must replay the same
    recovery trajectory)."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"resilience/(\w+)/(\w+)/(\w+)", name)
        if not m:
            continue
        d = dict(kv.split("=", 1) for kv in derived.split())
        rec: dict = {"step_us": round(us, 1)}
        for k, v in d.items():
            rec[k] = int(v)
        out[f"{m.group(1)}/{m.group(2)}/{m.group(3)}"] = rec
    return out


def parse_residency(rows) -> dict:
    """``residency/<model>/<ds>/c<C>`` rows -> {case: record}.

    ``na_us`` is the latency wall (recorded, never gated); the counters are
    deterministic host-side degree-ordering output — hits/misses/rows replay
    exactly, so ``--check`` compares them at exact equality."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"residency/(\w+)/(\w+)/c(\d+)", name)
        if not m:
            continue
        d = dict(kv.split("=", 1) for kv in derived.split())
        out[f"{m.group(1)}/{m.group(2)}/c{m.group(3)}"] = {
            "na_us": round(us, 1),
            "cache_rows": int(d["cache_rows"]),
            "hits": int(d["hits"]),
            "misses": int(d["misses"]),
            "rows": int(d["rows"]),
            "hit_rate": float(d["hit_rate"]),
            "na_hbm_bytes": float(d["na_hbm_bytes"]),
            "bytes_saved": float(d["bytes_saved"]),
        }
    return out


def parse_overlap(rows) -> dict:
    """``overlap/<model>/<ds>/<case>/(dag|parity|accounting)`` rows ->
    {case: record}.

    The DAG counters and the bit-exactness flag are plan-derived
    deterministic output (``--check`` compares them EXACTLY); the
    critical-path / serial-sum accounting walls are recorded for the
    handbook but never gated."""
    out: dict = {}
    for name, us, derived in rows or []:
        m = re.fullmatch(r"overlap/(\w+)/(\w+)/(\w+)/(dag|parity|accounting)",
                         name)
        if not m:
            continue
        rec = out.setdefault(f"{m.group(1)}/{m.group(2)}/{m.group(3)}", {})
        d = dict(kv.split("=", 1) for kv in derived.split())
        if m.group(4) == "accounting":
            rec.update({k: round(float(v), 1) for k, v in d.items()})
        else:
            rec.update({k: int(v) for k, v in d.items()})
    return out


def check_regression(results: dict, threshold: float = 0.20) -> None:
    """Bench-regression gate: diff the fresh NA/SA stage costs against the
    committed ``BENCH_hgnn.json``; fail on >``threshold`` regression.

    Two comparisons per case/stage: wall time (gated behind an absolute
    floor — CPU CI timers are noisy and the committed numbers come from a
    different machine) and the characterization records (FLOPs / HBM bytes
    from the compiled HLO — deterministic, so no floor: a >20% byte or FLOP
    growth is a real code regression regardless of the runner).

    The comparison covers EVERY stage the committed snapshot records for a
    case the fresh run reproduced — including the partitioned flow's
    halo-exchange stage — and a committed stage that is *missing* from the
    fresh run fails loudly instead of silently passing (a disappeared stage
    usually means the breakdown regexes and the executor drifted apart).
    The ``partition`` section gates the same way: halo traffic is
    deterministic partitioner output, so byte/cut drift needs no floor."""
    sb = results.get("bench_stage_breakdown")
    pt = results.get("bench_partition")
    ly = results.get("bench_layers")
    sv = results.get("bench_serving")
    rz = results.get("bench_resilience")
    rd = results.get("bench_residency")
    ov = results.get("bench_overlap")
    if (not sb and not pt and not ly and not sv and not rz and not rd
            and not ov) or not BENCH_JSON.exists():
        return
    try:
        committed = json.loads(BENCH_JSON.read_text())
    except json.JSONDecodeError:
        return
    old = committed.get("stage_breakdown_us", {})
    old_char = committed.get("stage_characterization", {})
    floor_us = float(os.environ.get("BENCH_GATE_FLOOR_US", "2000"))
    regressions = []

    def gate_wall(label, prev, new):
        if prev and new and new > prev * (1 + threshold) \
                and new - prev > floor_us:
            regressions.append(f"{label}: {prev:.0f} -> {new:.0f} us "
                               f"(+{100 * (new / prev - 1):.0f}%)")

    if sb:
        fresh = parse_breakdown(sb)
        if not fresh and old:
            # the module produced rows but the parser recognized none: the
            # row naming and the gate drifted apart — exactly the silent
            # pass this gate exists to prevent
            regressions.append("bench_stage_breakdown rows parsed to zero "
                               "cases (row naming / gate regex drift?)")
        for case, stages in fresh.items():
            for stage in sorted(set(old.get(case, {})) | set(stages)):
                prev, new = old.get(case, {}).get(stage), stages.get(stage)
                if prev and new is None:
                    regressions.append(
                        f"{case}/{stage}: recorded stage missing from the "
                        "fresh run")
                    continue
                gate_wall(f"{case}/{stage}", prev, new)
        for case, stages in parse_characterization(sb).items():
            for stage in sorted(set(old_char.get(case, {})) | set(stages)):
                prev = old_char.get(case, {}).get(stage)
                new = stages.get(stage)
                if prev and new is None:
                    regressions.append(
                        f"{case}/{stage}: recorded characterization missing "
                        "from the fresh run")
                    continue
                if not prev or not new:
                    continue
                for metric in ("flops", "hbm_bytes"):
                    if new[metric] > prev[metric] * (1 + threshold):
                        regressions.append(
                            f"{case}/{stage} {metric}: {prev[metric]:.3g} -> "
                            f"{new[metric]:.3g} "
                            f"(+{100 * (new[metric] / prev[metric] - 1):.0f}%)")
    if pt:
        # Wall times in this section sit at the tens-of-ms scale where
        # shared-runner noise swings 3x, so they are recorded (for the
        # handbook) but not gated — the gate covers stage PRESENCE and the
        # partitioner's deterministic outputs (halo bytes / cut edges are
        # exact re-runs of the same host algorithm on the same graph).
        old_part = committed.get("partition", {})
        fresh_part = parse_partition(pt)
        if not fresh_part and old_part:
            regressions.append("bench_partition rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        for case, rec in fresh_part.items():
            prev = old_part.get(case)
            if not prev:
                continue
            for stage_key in ("NA_us", "gather_halo_us"):
                if stage_key in prev and stage_key not in rec:
                    regressions.append(f"partition/{case}/{stage_key}: "
                                       "recorded stage missing from the "
                                       "fresh run")
            for metric in ("halo_bytes", "cut_edges"):
                pv, nv = prev.get(metric), rec.get(metric)
                if pv and nv is not None and nv > pv * (1 + threshold):
                    regressions.append(
                        f"partition/{case} {metric}: {pv:.3g} -> {nv:.3g} "
                        f"(+{100 * (nv / pv - 1):.0f}%)")
    if ly:
        # depth sweep: wall times stay ungated (tens-of-ms CPU noise); the
        # gate covers per-layer stage PRESENCE, the deterministic per-layer
        # characterization records, and the halo-bytes x L traffic (exact
        # re-runs of the same partitioner + HLO walk)
        old_layers = committed.get("layers", {})
        fresh_layers = parse_layers(ly)
        if not fresh_layers and old_layers:
            regressions.append("bench_layers rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        for case, rec in fresh_layers.items():
            prev = old_layers.get(case)
            if not prev:
                continue
            for st in prev.get("stages_us", {}):
                if st not in rec.get("stages_us", {}):
                    regressions.append(f"layers/{case}/{st}: recorded stage "
                                       "missing from the fresh run")
            for st, pm in prev.get("char", {}).items():
                nm = rec.get("char", {}).get(st)
                if nm is None:
                    regressions.append(f"layers/{case}/char/{st}: recorded "
                                       "characterization missing from the "
                                       "fresh run")
                    continue
                for metric in ("flops", "hbm_bytes"):
                    if not pm[metric]:
                        # zero baseline (e.g. RGCN's identity hidden FP has
                        # zero FLOPs): any appearance of work is a change
                        # worth flagging, and there is no percent to compute
                        if nm[metric]:
                            regressions.append(
                                f"layers/{case}/{st} {metric}: 0 -> "
                                f"{nm[metric]:.3g}")
                        continue
                    if nm[metric] > pm[metric] * (1 + threshold):
                        regressions.append(
                            f"layers/{case}/{st} {metric}: {pm[metric]:.3g} "
                            f"-> {nm[metric]:.3g} "
                            f"(+{100 * (nm[metric] / pm[metric] - 1):.0f}%)")
            if prev.get("halo") and not rec.get("halo"):
                regressions.append(f"layers/{case}/halo: recorded halo "
                                   "record missing from the fresh run")
            for metric in ("halo_bytes", "halo_bytes_total"):
                pv = prev.get("halo", {}).get(metric)
                nv = rec.get("halo", {}).get(metric)
                if pv and nv is not None and nv > pv * (1 + threshold):
                    regressions.append(
                        f"layers/{case} {metric}: {pv:.3g} -> {nv:.3g} "
                        f"(+{100 * (nv / pv - 1):.0f}%)")
    if sv:
        # serving gate: wall latencies are recorded but NEVER gated (the
        # partition-section convention); the gate covers the deterministic
        # quantities only — sampled frontier bytes and bucket-ladder hit
        # counts are exact re-runs of the same host sampler on the same
        # graph and queue, and the post-warmup recompile count must stay 0
        old_serving = committed.get("serving", {})
        fresh_serving = parse_serving(sv)
        if not fresh_serving and old_serving:
            regressions.append("bench_serving rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        for case, rec in fresh_serving.items():
            prev = old_serving.get(case)
            if not prev:
                continue
            if rec["recompiles"] > prev.get("recompiles", 0):
                regressions.append(
                    f"serving/{case} recompiles: {prev.get('recompiles', 0)} "
                    f"-> {rec['recompiles']} (post-warmup compilation — a "
                    "batch shape escaped the ladder)")
            pv = prev.get("frontier_bytes")
            if pv and rec["frontier_bytes"] > pv * (1 + threshold):
                regressions.append(
                    f"serving/{case} frontier_bytes: {pv:.3g} -> "
                    f"{rec['frontier_bytes']:.3g} "
                    f"(+{100 * (rec['frontier_bytes'] / pv - 1):.0f}%)")
            old_hits = {int(k): v
                        for k, v in prev.get("rung_hits", {}).items()}
            for rung, n_prev in old_hits.items():
                n_new = rec["rung_hits"].get(rung, 0)
                if n_prev and n_new > n_prev * (1 + threshold):
                    regressions.append(
                        f"serving/{case} rung_hits[{rung}]: {n_prev} -> "
                        f"{n_new} (ladder dispatch drift)")
    if rz:
        # resilience gate: counters replay a seeded fault schedule over a
        # fixed queue, so the comparison is EXACT equality — any drift in
        # retries / failed requests / shed / degrade levels / failover
        # outcome is a recovery-path behavior change, not noise.  Walls
        # (step_us) stay ungated as everywhere else.
        old_rz = committed.get("resilience", {})
        fresh_rz = parse_resilience(rz)
        if not fresh_rz and old_rz:
            regressions.append("bench_resilience rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        for case, rec in fresh_rz.items():
            prev = old_rz.get(case)
            if not prev:
                continue
            for key in sorted(set(prev) - {"step_us"}):
                if key not in rec:
                    regressions.append(
                        f"resilience/{case} {key}: recorded counter missing "
                        "from the fresh run")
                elif rec[key] != prev[key]:
                    regressions.append(
                        f"resilience/{case} {key}: {prev[key]} -> {rec[key]} "
                        "(seeded chaos counters must replay exactly)")
    if rd:
        # residency gate: the hit/miss counters are deterministic output of
        # the degree ordering over the same graph's gather tables, so the
        # comparison is EXACT equality — any drift means the hot-set
        # selection or the reference counting changed, not noise.  The NA
        # bytes after the cache accounting are deterministic too (HLO walk
        # minus counters) and gate at the usual growth threshold; walls
        # (na_us) stay ungated as everywhere else.
        old_rd = committed.get("residency", {})
        fresh_rd = parse_residency(rd)
        if not fresh_rd and old_rd:
            regressions.append("bench_residency rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        for case, rec in fresh_rd.items():
            prev = old_rd.get(case)
            if not prev:
                continue
            for key in ("cache_rows", "hits", "misses", "rows"):
                if key not in rec:
                    regressions.append(
                        f"residency/{case} {key}: recorded counter missing "
                        "from the fresh run")
                elif rec[key] != prev.get(key):
                    regressions.append(
                        f"residency/{case} {key}: {prev.get(key)} -> "
                        f"{rec[key]} (degree-ordered counters must replay "
                        "exactly)")
            pv = prev.get("na_hbm_bytes")
            if pv and rec["na_hbm_bytes"] > pv * (1 + threshold):
                regressions.append(
                    f"residency/{case} na_hbm_bytes: {pv:.3g} -> "
                    f"{rec['na_hbm_bytes']:.3g} "
                    f"(+{100 * (rec['na_hbm_bytes'] / pv - 1):.0f}%)")
    if ov:
        # overlap gate: the stage DAG is a pure function of the plan and
        # the bit-exactness flag must never drop, so both compare at EXACT
        # equality; the critical-path / serial-sum walls stay ungated as
        # everywhere else.
        old_ov = committed.get("overlap", {})
        fresh_ov = parse_overlap(ov)
        if not fresh_ov and old_ov:
            regressions.append("bench_overlap rows parsed to zero cases "
                               "(row naming / gate regex drift?)")
        det_keys = ("depth", "stages", "edges", "concurrent_pairs",
                    "overlapped_stages", "bitexact")
        for case, rec in fresh_ov.items():
            prev = old_ov.get(case)
            if not prev:
                continue
            for key in det_keys:
                if key not in prev:
                    continue
                if key not in rec:
                    regressions.append(
                        f"overlap/{case} {key}: recorded counter missing "
                        "from the fresh run")
                elif rec[key] != prev[key]:
                    regressions.append(
                        f"overlap/{case} {key}: {prev[key]} -> {rec[key]} "
                        "(plan-derived schedule counters must replay "
                        "exactly)")
    if regressions:
        raise SystemExit("bench regression gate (>"
                         f"{int(threshold * 100)}% vs {BENCH_JSON.name}): "
                         + "; ".join(regressions))
    print(f"# bench regression gate OK (vs {BENCH_JSON.name})", flush=True)


def write_bench_json(results: dict) -> None:
    """Fold HGNN trajectory rows into BENCH_hgnn.json.

    Merges into the existing file so running one module never clobbers the
    other module's committed section; only called when every selected
    module succeeded."""
    data: dict = {"schema": 1, "source": "benchmarks/run.py"}
    if BENCH_JSON.exists():
        try:
            data.update(json.loads(BENCH_JSON.read_text()))
        except json.JSONDecodeError:
            pass  # rewrite a corrupt baseline from scratch
    sb = results.get("bench_stage_breakdown")
    if sb:
        for name, us, derived in sb:
            if name == "fig2/avg_NA_share":
                m2 = re.search(r"avg_na_share=([\d.]+)", derived)
                if m2:
                    data["avg_na_share_pct"] = float(m2.group(1))
        # merge per case: a BENCH_SMOKE run (one case) must not shrink the
        # committed multi-case baseline
        data.setdefault("stage_breakdown_us", {}).update(parse_breakdown(sb))
        data.setdefault("stage_characterization", {}).update(
            parse_characterization(sb))
    nf = results.get("bench_na_fused")
    if nf:
        fused: dict = {}
        for name, us, derived in nf:
            if name == "na_fused/csr_baseline":
                fused["baseline_csr_us"] = round(us, 1)
            elif name == "na_fused/padded_per_head":
                fused["per_head_us"] = round(us, 1)
                m = re.search(r"na_launches=(\d+)", derived)
                fused["na_launches_per_head"] = int(m.group(1)) if m else None
            elif name == "na_fused/fused_all_heads":
                fused["fused_us"] = round(us, 1)
                m = re.search(r"speedup_vs_csr=([\d.]+)x", derived)
                fused["speedup_vs_baseline"] = float(m.group(1)) if m else None
                fused["na_launches_fused"] = 1
            elif name == "na_fused/bucketed_xla":
                fused["bucketed_us"] = round(us, 1)
                m = re.search(r"speedup_vs_csr=([\d.]+)x", derived)
                fused["bucketed_speedup_vs_csr"] = (float(m.group(1))
                                                    if m else None)
            elif name == "na_fused/kernel_interpret_parity":
                m = re.search(r"max_abs_err=([\d.e+-]+)", derived)
                fused["kernel_max_abs_err"] = float(m.group(1)) if m else None
        data["na_fused"] = fused
    se = results.get("bench_sa_epilogue")
    if se:
        epi: dict = {}
        for name, us, derived in se:
            d = dict(kv.split("=", 1) for kv in derived.split())
            if name == "sa_epilogue/two_pass":
                epi["two_pass_us"] = round(us, 1)
                epi["two_pass_hbm_bytes"] = float(d["hbm_bytes"])
                epi["z_bytes"] = float(d["z_bytes"])
            elif name == "sa_epilogue/fused":
                epi["fused_us"] = round(us, 1)
                epi["fused_hbm_bytes"] = float(d["hbm_bytes"])
                epi["z_passes_saved"] = float(d["z_passes_saved"])
            elif name == "sa_epilogue/kernel_interpret_parity":
                epi["kernel_max_abs_err"] = float(d["max_abs_err"])
        data["sa_epilogue"] = epi
    pt = results.get("bench_partition")
    if pt:
        # merge per case so a BENCH_SMOKE run (one model, two Ks) never
        # shrinks the committed multi-case sweep
        data.setdefault("partition", {}).update(parse_partition(pt))
    ly = results.get("bench_layers")
    if ly:
        # merge per case so a BENCH_SMOKE run (one model, two depths) never
        # shrinks the committed depth sweep
        data.setdefault("layers", {}).update(parse_layers(ly))
    sv = results.get("bench_serving")
    if sv:
        # merge per case so a BENCH_SMOKE run (one case, one slot plan)
        # never shrinks the committed serving sweep
        data.setdefault("serving", {}).update(parse_serving(sv))
    rz = results.get("bench_resilience")
    if rz:
        # merge per case so a BENCH_SMOKE run (one chaos case + failover)
        # never shrinks the committed chaos sweep
        data.setdefault("resilience", {}).update(parse_resilience(rz))
    rd = results.get("bench_residency")
    if rd:
        # merge per case so a BENCH_SMOKE run (one case, two capacities)
        # never shrinks the committed capacity sweep
        data.setdefault("residency", {}).update(parse_residency(rd))
    ov = results.get("bench_overlap")
    if ov:
        # merge per case so a BENCH_SMOKE run (one case per overlap source)
        # never shrinks the committed overlap sweep
        data.setdefault("overlap", {}).update(parse_overlap(ov))
    if sb or nf or se or pt or ly or sv or rz or rd or ov:
        BENCH_JSON.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {BENCH_JSON.name}", flush=True)


def main() -> None:
    import importlib

    argv = sys.argv[1:]
    check = "--check" in argv
    only = [a for a in argv if a != "--check"] or None
    print("name,us_per_call,derived")
    failures = 0
    results: dict = {}
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            from benchmarks.common import emit

            rows = mod.run()
            emit(rows)
            results[name] = rows
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED\n{traceback.format_exc()}", flush=True)
    if not failures:  # never record a partial/failed run as the baseline
        if check:  # gate against the committed snapshot BEFORE overwriting
            check_regression(results)
        write_bench_json(results)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
