"""Fused multi-head GAT-NA vs the baseline NA executions.

Three rungs of the NA trajectory, timed jitted on the host backend:

* ``csr_baseline``    — the DGL-faithful baseline path this repo (and the
  paper) profiles: flat edge list + ``segment_max``/``segment_sum``
  scatters (SDDMMCoo/SpMMCsr analogues).  This is what ``cfg.fused=False``
  runs and what "baseline NA" means across the codebase.
* ``padded_per_head`` — the seed's split padded execution: edge scores in
  XLA (one gather of the source table for the SDDMM) + ONE ``segment_spmm``
  per head (H more gathers, H+1 NA launches per subgraph).
* ``fused_all_heads`` — the one-launch formulation ``kernels/gat_na.py``
  hard-codes (``ref.gat_na`` is its math): SDDMM + segment-softmax +
  weighted reduce for all heads around a single gather.

Pallas interpret mode is an emulator, not a timing harness, so the timing
rows compare the *formulations* at the XLA level; the kernel itself is
parity-checked here in interpret mode (and swept in tests/test_gat_na.py).
On CPU the per-head loop can locally beat the all-heads form (smaller
cache-resident tiles); the headline speedup is fused vs the CSR baseline,
and the launch-count reduction (H+1 -> 1) is what carries to the TPU.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import metapath as mp, stages
from repro.data.synthetic import make_imdb
from repro.kernels import ref
from repro.kernels.gat_na import gat_na

N_HEADS = 8
HEAD_DIM = 8


def _per_head_split(p, h_dst, h_src, nbr, mask):
    """The seed's split execution: XLA SDDMM gather + per-head spmm loop."""
    e_dst = (h_dst * p["a_dst"]).sum(-1)
    e_src = (h_src * p["a_src"]).sum(-1)
    e = e_dst[:, None, :] + e_src[nbr]  # gather #1 (scores)
    e = jnp.where(e >= 0, e, 0.2 * e)
    e = jnp.where(mask[..., None] > 0, e, -1e9)
    e = e - jax.lax.stop_gradient(e.max(axis=1, keepdims=True))
    w = jnp.exp(e) * mask[..., None]
    alpha = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    outs = [
        ref.segment_spmm(h_src[:, hh, :], nbr, alpha[:, :, hh], mean=False)
        for hh in range(h_src.shape[1])  # gathers #2..#H+1, one per head
    ]
    return jnp.stack(outs, axis=1)


def run() -> list:
    rows: list = []
    hg = make_imdb()
    path = ["M", "D", "M"]
    sub = mp.build_padded(hg, path, max_degree=32)
    csr = mp.build_csr(hg, path)
    seg, idx = stages.csr_to_edges(csr.indptr, csr.indices)
    seg, idx = jnp.asarray(seg), jnp.asarray(idx)
    n = sub.n_nodes
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((n, N_HEADS, HEAD_DIM)), jnp.float32)
    p = stages.init_gat(jax.random.key(0), N_HEADS, HEAD_DIM)
    nbr = jnp.asarray(sub.nbr)
    mask = jnp.asarray(sub.mask)

    csr_fn = jax.jit(lambda p, h: stages.gat_aggregate_csr(p, h, h, seg, idx, n))
    split_fn = jax.jit(_per_head_split)
    fused_fn = jax.jit(lambda p, hd, hs, nn, mm: ref.gat_na(p, hd, hs, nn, mm))
    out_s = split_fn(p, h, h, nbr, mask)
    out_f = fused_fn(p, h, h, nbr, mask)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_f),
                               rtol=2e-3, atol=2e-3)

    t_c = time_jitted(csr_fn, p, h, iters=3, warmup=1)
    t_s = time_jitted(split_fn, p, h, h, nbr, mask)
    t_f = time_jitted(fused_fn, p, h, h, nbr, mask)
    # Launch accounting for the NA hot loop (per metapath subgraph):
    # csr = per-edge SDDMM + segment-max + segment-sum scatter chain;
    # split = 1 XLA score pass + N_HEADS spmm kernels; fused = 1 kernel.
    rows.append(("na_fused/csr_baseline", t_c,
                 f"edges={int(seg.shape[0])} dgl_faithful_baseline"))
    rows.append(("na_fused/padded_per_head", t_s,
                 f"na_launches={N_HEADS + 1} gathers={N_HEADS + 1} "
                 f"speedup_vs_csr={t_c / max(t_s, 1e-9):.2f}x"))
    rows.append(("na_fused/fused_all_heads", t_f,
                 f"na_launches=1 gathers=1 "
                 f"speedup_vs_csr={t_c / max(t_f, 1e-9):.2f}x "
                 f"vs_per_head={t_s / max(t_f, 1e-9):.2f}x"))

    # degree-bucketed padded NA vs the CSR baseline (ROADMAP: record the
    # bucket win instead of asserting it): rows binned into 3 quantile
    # K-caps, each bucket a dense launch at its own degree cap
    bk = mp.bucket_padded(sub, 3)
    buckets = [(jnp.asarray(bk.row_ids[i]), jnp.asarray(bk.nbr[i]),
                jnp.asarray(bk.mask[i])) for i in range(bk.n_buckets)]
    bucketed_fn = jax.jit(
        lambda p, h: stages.gat_aggregate_bucketed(p, h, h, buckets))
    out_b = bucketed_fn(p, h)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_f),
                               rtol=2e-3, atol=2e-3)
    t_b = time_jitted(bucketed_fn, p, h)
    rows.append(("na_fused/bucketed_xla", t_b,
                 f"n_buckets={bk.n_buckets} "
                 f"speedup_vs_csr={t_c / max(t_b, 1e-9):.2f}x"))

    # kernel parity (interpret mode) on a slice — cheap CI guard
    sl = 128 if os.environ.get("BENCH_SMOKE") else 512
    got = gat_na(p, h[:sl], h, nbr[:sl], mask[:sl], block_n=64,
                 interpret=True)
    want = ref.gat_na(p, h[:sl], h, nbr[:sl], mask[:sl])
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, err
    rows.append(("na_fused/kernel_interpret_parity", 0.0,
                 f"max_abs_err={err:.2e}"))
    return rows


if __name__ == "__main__":
    emit(run())
