"""Request-path serving: neighbor-sampled minibatches through the slot-based
continuous-batching engine (repro.serve).

Per case (model/dataset × slot count): a fixed deterministic request queue
(``default_rng(0)`` target ids, mixed sizes) is served end to end after the
per-rung warmup.  Rows record

* the mean per-step wall (us) — latency, recorded for the handbook but NOT
  gated (shared-runner CPU noise swings walls 3x);
* the deterministic serving quantities ``run.py --check`` gates: sampled
  frontier bytes, bucket-ladder hit counts, step count, and the post-warmup
  recompile count (must stay 0 — the ladder is the whole shape space).

Rows fold into ``BENCH_hgnn.json`` under ``serving``.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit
from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import make_dataset
from repro.serve.engine import HGNNRequest, HGNNServeEngine
from repro.serve.sampler import HGNNSampler

CASES = [("han", "imdb"), ("rgcn", "imdb")]
SLOTS = (4, 8)
N_REQUESTS = 32
FANOUT = 8
if os.environ.get("BENCH_SMOKE"):  # CI smoke: one case, one slot plan
    CASES = [("han", "imdb")]
    SLOTS = (8,)


def run() -> list:
    import jax

    rows: list = []
    for model, ds in CASES:
        hg = make_dataset(ds)
        cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                         n_classes=8, max_degree=32, fused=True,
                         fanout=FANOUT)
        m = get_model(cfg)
        batch = m.prepare(hg)
        params = m.init(jax.random.key(0), batch)
        fn = jax.jit(m.forward)
        sampler = HGNNSampler(m.plan(), cfg, hg)
        n_t = hg.node_counts[m.plan().target]
        for slots in SLOTS:
            engine = HGNNServeEngine(m.executor, params, sampler,
                                     slots=slots, slot_targets=4, fn=fn)
            engine.warmup()
            rng = np.random.default_rng(0)
            reqs = [HGNNRequest(targets=rng.integers(
                0, n_t, size=int(rng.integers(1, 9))))
                for _ in range(N_REQUESTS)]
            n_targets = sum(len(r.targets) for r in reqs)
            engine.serve(reqs)
            st = engine.stats()
            rungs = ";".join(f"{i}:{n}" for i, n in st["rung_hits"].items())
            rows.append((
                f"serving/{model}/{ds}/s{slots}",
                st["wall_mean_ms"] * 1e3,
                f"requests={N_REQUESTS} targets={n_targets} "
                f"steps={st['steps']} "
                f"recompiles={st['compiles_after_warmup']} "
                f"frontier_bytes={st['frontier_bytes']:.0f} "
                f"truncated={st['truncated_rows']} rung_hits={rungs} "
                f"throughput_tps="
                f"{n_targets / max(st['wall_total_s'], 1e-9):.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
