"""Guidelines §5 (beyond-paper optimizations), measured:

  (b) subgraph-level FP+NA fusion, BOTH algebraic orders:
      aggregate-raw-then-project (linearity) vs project-then-aggregate.
      MEASURED OUTCOME (see EXPERIMENTS.md §Perf, hypothesis H-F1): the
      aggregate-first order LOSES badly whenever raw_dim >> hidden (every
      HGNN dataset here) because the K-neighbor gather re-reads raw rows
      per edge (E x F bytes) instead of d-dim projected rows (E x d).
      The TPU-correct fusion is project-then-aggregate with the projected
      tile resident in VMEM — which is exactly kernels/segment_spmm.py.
      The guideline's fusion only pays off when F < d (never for raw
      features); ops-level dispatch picks the cheap order by byte model.
  (-) concat-free Semantic Aggregation — stacked [P,N,D] layout vs explicit
      list-stack (the DR-Type CatArrayBatchedCopy analogue): a clean win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jitted
from repro.core import metapath as mp, semantics, stages
from repro.core.characterize import analyze_hlo_text
from repro.data.synthetic import make_imdb
from repro.kernels import ref as kref


def run() -> list:
    rows: list = []
    hg = make_imdb()
    sub = mp.build_padded(hg, ["M", "D", "M"], max_degree=32)
    x = jnp.asarray(hg.features["M"])  # [N, 3066] raw
    n, f = x.shape
    d = 64
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((f, d)).astype(np.float32) / np.sqrt(f))
    nbr = jnp.asarray(sub.nbr)
    mask = jnp.asarray(sub.mask)

    # baseline: FP for every node, then aggregate projected vectors
    base = jax.jit(lambda x, w: kref.segment_spmm(x @ w, nbr, mask, mean=True))
    # fused (guideline b): aggregate raw, project the aggregate
    fused = jax.jit(lambda x, w: kref.fused_fp_na(x, w, nbr, mask, mean=True))

    out_b, out_f = base(x, w), fused(x, w)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_f),
                               rtol=2e-3, atol=2e-3)

    t_b = time_jitted(base, x, w)
    t_f = time_jitted(fused, x, w)
    rep_b = analyze_hlo_text(base.lower(x, w).compile().as_text())
    rep_f = analyze_hlo_text(fused.lower(x, w).compile().as_text())
    rows.append(("guideline_b/fp_na_baseline", t_b,
                 f"bytes={rep_b['total_hbm_bytes']:.3g} flops={rep_b['total_flops']:.3g}"))
    rows.append(("guideline_b/fp_na_fused", t_f,
                 f"bytes={rep_f['total_hbm_bytes']:.3g} flops={rep_f['total_flops']:.3g} "
                 f"speedup={t_b / max(t_f, 1e-9):.2f}x"))

    # concat-free SA
    p = semantics.init_semantic_attention(jax.random.key(0), d, 128)
    z_list = [jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
              for _ in range(4)]
    z_stacked = jnp.stack(z_list)
    sa_list = jax.jit(lambda *zs: semantics.semantic_attention_list(p, list(zs)))
    sa_stack = jax.jit(lambda z: semantics.semantic_attention(p, z))
    t_l = time_jitted(sa_list, *z_list)
    t_s = time_jitted(sa_stack, z_stacked)
    rep_l = analyze_hlo_text(sa_list.lower(*z_list).compile().as_text())
    dr = rep_l["hbm_bytes_by_class"].get("DR", 0.0)
    rows.append(("guideline_dr/sa_with_concat", t_l,
                 f"DR_bytes={dr:.3g}"))
    rows.append(("guideline_dr/sa_concat_free", t_s,
                 f"speedup={t_l / max(t_s, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
