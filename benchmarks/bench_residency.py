"""Hot-feature residency: hit-rate vs NA HBM-bytes sweep.

Sweeps the cache capacity (``cfg.cache_rows``) for HAN (stacked metapath
layout) and RGCN (per-relation padded layout) on IMDB and records, per C:

* the deterministic cache counters (``repro.core.residency`` — hits, misses,
  total gathered rows, hit rate) from one full pass over the plan's gather
  tables;
* what the cache does to the dominant stage — the NA record's ``hbm_bytes``
  after the residency accounting (hits x row_bytes saved per layer, fill
  charged once) and the NA wall time;
* the saved bytes themselves (``bytes_saved_total``), the paper-facing
  "N% of NA traffic is re-gathered hot rows" quantity.

C=0 is the uncached baseline.  The degree ordering is a deterministic
host-side computation, so the counters replay exactly run to run —
``benchmarks/run.py --check`` gates them at exact equality (walls stay
ungated, the repo-wide convention).  Rows fold into ``BENCH_hgnn.json``
under ``residency``.
"""
from __future__ import annotations

import os

import jax

from benchmarks.common import emit, time_jitted
from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import make_dataset

CASES = [("han", "imdb"), ("rgcn", "imdb")]
CAPACITIES = (0, 64, 256, 1024)
if os.environ.get("BENCH_SMOKE"):  # CI smoke: cheapest case under a timeout
    CASES = [("han", "imdb")]
    CAPACITIES = (0, 256)


def run() -> list:
    rows: list = []
    for model, ds in CASES:
        hg = make_dataset(ds)
        for c in CAPACITIES:
            cfg = HGNNConfig(model=model, dataset=ds, hidden=64, n_heads=8,
                             n_classes=8, max_degree=32, fused=True,
                             cache_rows=c)
            m = get_model(cfg)
            batch = m.prepare(hg)
            params = m.init(jax.random.key(0), batch)
            fns = m.executor.stage_fns(params, batch)
            na_fn, na_args = fns["NA"]
            na_us = time_jitted(na_fn, *na_args)
            recs = m.stage_records(params, batch)
            na_bytes = recs["stages"]["NA"]["hbm_bytes"]
            if c:
                rr = recs["residency"]
                derived = (f"cache_rows={rr['cache_rows']} "
                           f"hits={rr['hits']} misses={rr['misses']} "
                           f"rows={rr['rows']} "
                           f"hit_rate={rr['hit_rate']:.4f} "
                           f"na_hbm_bytes={na_bytes:.0f} "
                           f"bytes_saved={rr['bytes_saved_total']:.0f}")
            else:
                derived = (f"cache_rows=0 hits=0 misses=0 rows=0 "
                           f"hit_rate=0.0000 na_hbm_bytes={na_bytes:.0f} "
                           f"bytes_saved=0")
            rows.append((f"residency/{model}/{ds}/c{c}", na_us, derived))
    return rows


if __name__ == "__main__":
    emit(run())
