"""Shared benchmark helpers: wall-clock timing of jitted callables + CSV rows."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def time_jitted(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
