"""Paper Fig. 6b — TOTAL inference time grows with the number of metapaths
(more subgraphs -> more NA and more SA work). Full HAN forward on IMDB."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_jitted
from repro.configs.base import HGNNConfig
from repro.core.models import get_model
from repro.data.synthetic import DATASET_METAPATHS, make_imdb

ALL = [["M", "D", "M"], ["M", "A", "M"],
       ["M", "D", "M", "D", "M"], ["M", "A", "M", "A", "M"]]


def run() -> list:
    rows: list = []
    hg = make_imdb()
    saved = DATASET_METAPATHS["imdb"]
    try:
        for k in range(1, len(ALL) + 1):
            DATASET_METAPATHS["imdb"] = ALL[:k]
            cfg = HGNNConfig(model="han", dataset="imdb", hidden=64, n_heads=8,
                             n_classes=8)
            m = get_model(cfg)
            batch = m.prepare(hg)
            params = m.init(jax.random.key(0), batch)
            fwd = jax.jit(lambda p: m.forward(p, batch))
            t = time_jitted(fwd, params)
            rows.append((f"fig6b/han_total/{k}_metapaths", t, ""))
    finally:
        DATASET_METAPATHS["imdb"] = saved
    return rows


if __name__ == "__main__":
    emit(run())
